"""Flash chunk-prefill attention: backend parity, exact masks, O(L·tile).

Three layers of guarantee, matching the package contract
(``repro.kernels.chunk_attention``):

  * **parity** — Pallas (interpret mode) and the streaming tile-loop
    fallback match the materialized oracle within float tolerance across
    GQA ratios, sliding-window + ring-wrap, length-0 padded rows, and the
    L = 1 decode case (floats may reorder; a tolerance gate is the honest
    comparison for online vs one-shot softmax);
  * **exact masks** — the *visible set* every backend realizes is probed
    key-by-key and must equal a first-principles brute force bit for bit,
    including the write-then-attend decode equivalence (the slot a token's
    own write evicts is invisible);
  * **footprint** — the streaming path never materializes the
    (L, cap + L) score block: asserted structurally on the jaxpr, not just
    benched, plus the analytic ``tracked_block_bytes`` accounting.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.chunk_attention.ops import (_select_tile, chunk_attention,
                                               tracked_block_bytes)
from repro.kernels.chunk_attention.ref import (chunk_attention_ref,
                                               chunk_mask, history_mask,
                                               reach_of)


def make_case(rng, b, L, kv, g, hd, cap, *, int8=True, wrap=False,
              lengths=None):
    """A random op input with a coherent ring: the last min(pos0, cap)
    positions before the chunk start are resident (wrap=True starts past
    cap so the ring has wrapped at least once)."""
    q = jnp.asarray(rng.standard_normal((b, L, kv, g, hd)), jnp.float32)
    kn = jnp.asarray(rng.standard_normal((b, L, kv, hd)), jnp.float32)
    vn = jnp.asarray(rng.standard_normal((b, L, kv, hd)), jnp.float32)
    if int8:
        kc = jnp.asarray(rng.integers(-127, 128, (b, cap, kv, hd)), jnp.int8)
        vc = jnp.asarray(rng.integers(-127, 128, (b, cap, kv, hd)), jnp.int8)
        ks = jnp.asarray(rng.uniform(0.005, 0.02, (b, cap, kv)), jnp.float32)
        vs = jnp.asarray(rng.uniform(0.005, 0.02, (b, cap, kv)), jnp.float32)
    else:
        kc = jnp.asarray(rng.standard_normal((b, cap, kv, hd)), jnp.float32)
        vc = jnp.asarray(rng.standard_normal((b, cap, kv, hd)), jnp.float32)
        ks = vs = None
    pb = np.full((b, cap), -1, np.int64)
    pos0 = np.zeros((b,), np.int64)
    for r in range(b):
        pos0[r] = (cap + rng.integers(1, cap) if wrap
                   else rng.integers(0, cap))
        for p in range(max(0, pos0[r] - cap), pos0[r]):
            pb[r, p % cap] = p
    positions = pos0[:, None] + np.arange(L)[None, :]
    if lengths is None:
        lengths = rng.integers(0, L + 1, (b,))
    return (q, kn, vn, kc, ks, vc, vs, jnp.asarray(pb, jnp.int32),
            jnp.asarray(positions, jnp.int32),
            jnp.asarray(lengths, jnp.int32))


CASES = [
    # (b, L, kv, g, hd, cap, window, int8, wrap)   — GQA ratios, windows,
    pytest.param(2, 8, 2, 2, 16, 32, None, True, False, id="gqa2x2-full"),
    pytest.param(2, 8, 1, 4, 16, 32, None, True, True, id="gqa1x4-wrap"),
    pytest.param(2, 8, 4, 1, 16, 32, 8, True, True, id="mha-window-wrap"),
    pytest.param(2, 6, 1, 3, 8, 24, 5, True, True, id="window5-wrap"),
    pytest.param(3, 1, 2, 2, 8, 16, None, True, True, id="decode-L1"),
    pytest.param(3, 1, 2, 2, 8, 16, 8, True, True, id="decode-L1-window"),
    pytest.param(2, 4, 2, 2, 8, 16, None, False, False, id="float-cache"),
]


class TestBackendParity:
    @pytest.mark.parametrize("backend", ["stream", "pallas"])
    @pytest.mark.parametrize("b,L,kv,g,hd,cap,window,int8,wrap", CASES)
    def test_matches_materialized_oracle(self, backend, b, L, kv, g, hd,
                                         cap, window, int8, wrap):
        """Online-softmax backends vs the materialized reference: the
        tolerance gate covers softmax reordering only — valid rows must
        agree to float-roundoff, not merely 'roughly'."""
        rng = np.random.default_rng(hash((b, L, kv, cap, int8)) % 2**31)
        args = make_case(rng, b, L, kv, g, hd, cap, int8=int8, wrap=wrap)
        ref = np.asarray(chunk_attention_ref(*args, window=window))
        got = np.asarray(chunk_attention(*args, window=window,
                                         backend=backend, tile=8))
        lengths = np.asarray(args[-1])
        for r in range(b):
            if lengths[r] or int(jnp.sum(args[7][r] >= 0)):  # anything visible
                np.testing.assert_allclose(
                    got[r, :max(lengths[r], 1)], ref[r, :max(lengths[r], 1)],
                    rtol=2e-5, atol=2e-5, err_msg=f"row {r}")

    def test_zero_length_rows_are_finite(self):
        """length-0 rows (free/decoding slots riding through a prefill
        dispatch) must come out finite on every backend — garbage is fine,
        NaN would poison the residual stream."""
        rng = np.random.default_rng(0)
        args = make_case(rng, 2, 4, 2, 2, 8, 16,
                         lengths=np.zeros((2,), np.int64))
        # empty ring too: nothing visible at all
        args = args[:7] + (jnp.full_like(args[7], -1),) + args[8:]
        for backend in ("stream", "pallas", "materialized"):
            out = np.asarray(chunk_attention(*args, backend=backend, tile=4))
            assert np.isfinite(out).all(), backend


def _visible_sets(op_out, n_keys):
    """Recover per-(row, query) visible key sets from probe outputs:
    ``op_out[s]`` is the op result with v == 1 at key s and 0 elsewhere,
    so key s is visible to (r, l) iff the output is positive."""
    b, L = op_out.shape[1], op_out.shape[2]
    vis = np.zeros((b, L, n_keys), bool)
    for s in range(n_keys):
        vis[:, :, s] = op_out[s, :, :, 0, 0, 0] > 1e-9
    return vis


class TestExactMasks:
    """The visible set is the exact part of the contract: probe it key by
    key (constant scores → uniform weights → a key's indicator value
    survives iff it is visible) and compare bit-for-bit."""

    @pytest.mark.parametrize("window", [None, 5, 8])
    @pytest.mark.parametrize("wrap", [False, True])
    def test_backends_realize_identical_visible_sets(self, window, wrap):
        b, L, kv, g, hd, cap = 2, 5, 1, 1, 4, 12
        rng = np.random.default_rng(7)
        base = make_case(rng, b, L, kv, g, hd, cap, int8=False, wrap=wrap)
        (q, kn, vn, kc, _, vc, _, pb, positions, lengths) = base
        zeros = jnp.zeros_like
        outs = {}
        for backend in ("materialized", "stream", "pallas"):
            probes = []
            for s in range(cap + L):
                v_ring = np.zeros((b, cap, kv, hd), np.float32)
                v_new = np.zeros((b, L, kv, hd), np.float32)
                if s < cap:
                    v_ring[:, s] = 1.0
                else:
                    v_new[:, s - cap] = 1.0
                probes.append(np.asarray(chunk_attention(
                    zeros(q), zeros(kn), jnp.asarray(v_new), zeros(kc), None,
                    jnp.asarray(v_ring), None, pb, positions, lengths,
                    window=window, backend=backend, tile=4)))
            outs[backend] = _visible_sets(np.stack(probes), cap + L)

        # first-principles brute force of the contract rule
        reach = reach_of(cap, window)
        pbn, pos, lens = map(np.asarray, (pb, positions, lengths))
        expect = np.zeros((b, L, cap + L), bool)
        for r in range(b):
            for l in range(L):
                for s in range(cap):
                    d = pos[r, l] - pbn[r, s]
                    expect[r, l, s] = pbn[r, s] >= 0 and 0 <= d < reach
                for j in range(L):
                    d = pos[r, l] - pos[r, j]
                    expect[r, l, cap + j] = j < lens[r] and 0 <= d < reach
        # the op's own mask helpers must agree with the brute force too
        np.testing.assert_array_equal(
            np.asarray(history_mask(pb, positions, reach)), expect[:, :, :cap])
        np.testing.assert_array_equal(
            np.asarray(chunk_mask(positions, lengths, reach)),
            expect[:, :, cap:])
        for backend, vis in outs.items():
            # compare only queries that see anything (all-masked rows are
            # defined-garbage: uniform for materialized, zero for online)
            any_vis = expect.any(-1)
            np.testing.assert_array_equal(vis[any_vis], expect[any_vis],
                                          err_msg=backend)

    def test_L1_reproduces_write_then_attend_decode(self):
        """The L = 1 masks equal the pre-PR-5 decode semantics (write the
        token into the ring, then attend the post-write ring): the entry at
        distance exactly cap — the one the write evicts — is invisible,
        everything else the old mask admitted is visible."""
        cap, window = 8, None
        for pos0 in (3, 8, 19):  # pre-wrap, boundary, wrapped
            pb = np.full((1, cap), -1, np.int64)
            for p in range(max(0, pos0 - cap), pos0):
                pb[0, p % cap] = p
            positions = np.asarray([[pos0]])
            reach = reach_of(cap, window)
            vis_new = np.asarray(history_mask(
                jnp.asarray(pb, jnp.int32), jnp.asarray(positions, jnp.int32),
                reach))[0, 0]
            # old semantics: write pos0 into slot pos0 % cap, then mask
            # (pc >= 0) & (pc <= pos) & (pos - pc < cap + 1)
            pb_post = pb.copy()
            pb_post[0, pos0 % cap] = pos0
            vis_old = ((pb_post[0] >= 0) & (pb_post[0] <= pos0)
                       & (pos0 - pb_post[0] < cap + 1))
            # post-write slot pos0%cap holds the token itself == the op's
            # in-chunk self key; ring visibility must match elsewhere
            self_slot = pos0 % cap
            np.testing.assert_array_equal(
                np.delete(vis_new, self_slot), np.delete(vis_old, self_slot),
                err_msg=f"pos0={pos0}")
            assert not vis_new[self_slot]  # evicted entry masked pre-write
            assert vis_old[self_slot]      # ...because old path read the
            # freshly written token there; the op reads it as the self key
            chunk_vis = np.asarray(chunk_mask(
                jnp.asarray(positions, jnp.int32),
                jnp.asarray([1], jnp.int32), reach))[0, 0, 0]
            assert chunk_vis


def _collect_eqns(jaxpr, out):
    for eqn in jaxpr.eqns:
        out.append(eqn)
        for v in eqn.params.values():
            _collect_sub(v, out)


def _collect_sub(v, out):
    if hasattr(v, "eqns"):
        _collect_eqns(v, out)
    elif hasattr(v, "jaxpr"):
        _collect_eqns(v.jaxpr, out)
    elif isinstance(v, (list, tuple)):
        for x in v:
            _collect_sub(x, out)


def _eqn_shapes(fn, *args, **kw):
    eqns = []
    _collect_eqns(jax.make_jaxpr(lambda *a: fn(*a, **kw))(*args).jaxpr, eqns)
    shapes = []
    for eqn in eqns:
        for var in eqn.outvars:
            aval = getattr(var, "aval", None)
            if aval is not None and getattr(aval, "shape", None) is not None:
                shapes.append((tuple(aval.shape),
                               np.dtype(aval.dtype).itemsize
                               * int(np.prod(aval.shape)) if aval.shape
                               else 0))
    return shapes


class TestStreamingFootprint:
    B, L, KV, G, HD, CAP = 2, 8, 2, 4, 16, 256

    def _args(self, cap):
        rng = np.random.default_rng(1)
        return make_case(rng, self.B, self.L, self.KV, self.G, self.HD, cap)

    def test_no_full_score_block_in_jaxpr(self):
        """Structural, not benched: the streaming jaxpr contains no
        intermediate with the (…, L, cap + L) score-block shape (the
        materialized jaxpr does), and its largest intermediate is strictly
        smaller."""
        cap, L = self.CAP, self.L
        args = self._args(cap)
        tile = 16
        full_block = {s for s, _ in _eqn_shapes(
            chunk_attention, *args, backend="materialized")
            if s[-1:] == (cap + L,)}
        assert full_block, "materialized path must build the full block"
        stream_shapes = _eqn_shapes(chunk_attention, *args,
                                    backend="stream", tile=tile)
        assert not any(s[-1:] == (cap + L,) or s[-1:] == (cap,)
                       for s, _ in stream_shapes
                       if len(s) >= 4), \
            "streaming path materialized a full-width score block"
        max_stream = max(nb for _, nb in stream_shapes)
        max_mat = max(nb for _, nb in _eqn_shapes(
            chunk_attention, *args, backend="materialized"))
        assert max_stream < max_mat

    def test_tracked_bytes_are_O_L_tile(self):
        """The analytic accounting the benchmark reports: streaming bytes
        stop growing with capacity once the tile saturates; materialized
        bytes grow linearly with capacity."""
        b, kv, g, L = self.B, self.KV, self.G, self.L
        stream = [tracked_block_bytes(b, kv, g, L, cap, backend="stream")
                  for cap in (1024, 2048, 4096)]
        mat = [tracked_block_bytes(b, kv, g, L, cap, backend="materialized")
               for cap in (1024, 2048, 4096)]
        assert stream[0] == stream[1] == stream[2]  # O(L·tile), cap-free
        assert mat[1] > 2 * mat[0] * 0.9 and mat[2] > 2 * mat[1] * 0.9
        tile = _select_tile(4096, L)
        assert stream[2] == 4 * b * kv * g * L * tile
        assert stream[2] * 4 <= mat[2]  # the structural win at 4k context

    def test_decode_uses_single_tile(self):
        """L = 1 must not pay loop machinery: tile selection hands decode
        the whole ring as one tile (the decode fast path)."""
        assert _select_tile(4096, 1) == 4096
        assert _select_tile(256, 64) < 256


class TestModelLevelBackends:
    """The rewired model paths agree across backends (tolerance-gated) and
    the engine threads EngineConfig.attn_backend through."""

    def test_prefill_chunk_backend_equivalence(self):
        from repro import configs
        from repro.models import init_decode_state, init_params, prefill_chunk

        base = configs.get_smoke_config("qwen2-1.5b").scaled(
            kv_cache_dtype="int8")
        params = init_params(base, jax.random.PRNGKey(0))
        rng = np.random.default_rng(3)
        toks = jnp.asarray(rng.integers(1, 500, (2, 8)), jnp.int32)
        lens = jnp.asarray([8, 5], jnp.int32)
        outs = {}
        for backend in ("stream", "materialized"):
            cfg = base.scaled(attn_backend=backend)
            st = init_decode_state(cfg, 2, 16)
            lg, st = prefill_chunk(params, cfg, st, {"tokens": toks}, lens)
            outs[backend] = (np.asarray(lg, np.float32), st)
        np.testing.assert_allclose(outs["stream"][0], outs["materialized"][0],
                                   rtol=2e-4, atol=2e-4)
        # ring bookkeeping (positions written/dropped) is backend-exact;
        # k/v payloads beyond layer 0 inherit the activations' float drift
        sa, sb = outs["stream"][1], outs["materialized"][1]
        for key in ("pos",):
            assert jnp.array_equal(sa[key], sb[key])
        assert jnp.array_equal(sa["blocks"]["b0"]["pos"],
                               sb["blocks"]["b0"]["pos"])
        for leaf_a, leaf_b in zip(jax.tree.leaves(sa), jax.tree.leaves(sb)):
            np.testing.assert_allclose(
                np.asarray(leaf_a, np.float32), np.asarray(leaf_b, np.float32),
                rtol=2e-3, atol=1.01)  # int8 leaves may flip one step

    def test_engine_threads_attn_backend(self):
        from repro import configs
        from repro.models import init_params
        from repro.serving import EngineConfig, SamplingParams, ServingEngine

        cfg = configs.get_smoke_config("qwen2-1.5b")
        params = init_params(cfg, jax.random.PRNGKey(0))
        eng = ServingEngine(params, cfg, EngineConfig(
            max_slots=1, capacity=16, attn_backend="materialized"))
        assert eng.cfg.attn_backend == "materialized"
        h = eng.submit([5, 9, 17], SamplingParams(max_new_tokens=2))
        assert len(h.result().tokens) == 2

    def test_memory_stats_accounting(self):
        from repro import configs
        from repro.core.ptqtp import PTQTPConfig
        from repro.core.quantize_model import quantize_tree
        from repro.models import init_params
        from repro.serving import EngineConfig, ServingEngine

        cfg = configs.get_smoke_config("qwen2-1.5b")
        params = init_params(cfg, jax.random.PRNGKey(0))
        qp, _ = quantize_tree(params, PTQTPConfig(group_size=32, t_max=2))
        eng = ServingEngine(qp, cfg, EngineConfig(max_slots=2, capacity=32,
                                                  preunpack_decode=True))
        mem = eng.memory_stats()
        assert mem["preunpack_decode"]
        # unpacked planes are int8 trits: exactly 4x the 2-bit packed bytes
        assert mem["resident_plane_bytes"] == 4 * mem["packed_plane_bytes"]
        assert mem["preunpack_ratio"] == pytest.approx(4.0)
        assert mem["resident_total_bytes"] >= (mem["resident_plane_bytes"]
                                               + mem["decode_state_bytes"])
        off = ServingEngine(qp, cfg, EngineConfig(max_slots=2, capacity=32,
                                                  preunpack_decode=False))
        assert off.memory_stats()["preunpack_ratio"] == pytest.approx(1.0)
