"""Runtime substrate: checkpoint atomicity/retention, preemption, stragglers."""

import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.ptqtp import PTQTPConfig
from repro.core.quantize_model import QuantizedKernel, quantize_kernel
from repro.runtime.checkpoint import (CheckpointManager, latest_step,
                                      load_checkpoint, save_checkpoint)
from repro.runtime.monitor import HeartbeatMonitor, StragglerDetector
from repro.runtime.preempt import PreemptionGuard


def _tree(seed=0):
    r = np.random.default_rng(seed)
    return {
        "params": {"w": jnp.asarray(r.standard_normal((4, 8), np.float32)),
                   "b": jnp.asarray(r.standard_normal((8,), np.float32))},
        "opt": {"m": {"w": jnp.zeros((4, 8)), "b": jnp.zeros((8,))},
                "count": jnp.int32(7)},
        "step": jnp.int32(42),
    }


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = _tree()
        save_checkpoint(tmp_path, 42, tree)
        step, loaded, _ = load_checkpoint(tmp_path)
        assert step == 42
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(loaded)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_quantized_kernel_roundtrip(self, tmp_path):
        w = jnp.asarray(np.random.default_rng(1)
                        .standard_normal((128, 64), np.float32))
        qk = quantize_kernel(w, PTQTPConfig(group_size=32, t_max=3))
        save_checkpoint(tmp_path, 1, {"layer": {"kernel": qk}})
        _, loaded, _ = load_checkpoint(tmp_path)
        lk = loaded["layer"]["kernel"]
        assert isinstance(lk, QuantizedKernel)
        assert (lk.d_in, lk.d_out, lk.group_size) == (128, 64, 32)
        np.testing.assert_array_equal(np.asarray(qk.t1p), lk.t1p)
        np.testing.assert_array_equal(np.asarray(qk.alpha), lk.alpha)

    def test_latest_points_to_newest(self, tmp_path):
        save_checkpoint(tmp_path, 1, _tree())
        save_checkpoint(tmp_path, 2, _tree(1))
        assert latest_step(tmp_path) == 2
        step, _, _ = load_checkpoint(tmp_path)
        assert step == 2

    def test_no_tmp_left_behind(self, tmp_path):
        save_checkpoint(tmp_path, 3, _tree())
        leftovers = [p for p in Path(tmp_path).iterdir() if ".tmp" in p.name]
        assert not leftovers

    def test_extra_metadata(self, tmp_path):
        save_checkpoint(tmp_path, 5, _tree(), extra={"rng": [1, 2]})
        _, _, extra = load_checkpoint(tmp_path)
        assert extra == {"rng": [1, 2]}

    def test_manager_retention(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), interval_steps=1, keep=2)
        for s in (1, 2, 3, 4):
            mgr.save(s, _tree(s))
        dirs = sorted(p.name for p in Path(tmp_path).glob("step_*"))
        assert dirs == ["step_00000003", "step_00000004"]
        step, _, _ = mgr.restore_latest()
        assert step == 4

    def test_should_save_interval(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), interval_steps=10)
        assert not mgr.should_save(5)
        assert mgr.should_save(10)
        assert not mgr.should_save(0)

    def test_missing_checkpoint_raises(self, tmp_path):
        with pytest.raises(FileNotFoundError):
            load_checkpoint(tmp_path)


class TestPreemption:
    def test_programmatic_request(self):
        with PreemptionGuard() as g:
            assert not g.preempted
            g.request()
            assert g.preempted

    def test_signal_delivery(self):
        import os
        import signal

        with PreemptionGuard(signals=(signal.SIGUSR1,)) as g:
            os.kill(os.getpid(), signal.SIGUSR1)
            assert g.wait(timeout=2.0)


class TestStragglers:
    def test_detection(self, tmp_path):
        run = str(tmp_path)
        now = time.time()
        for host, (step_t, age) in enumerate([(1.0, 0), (1.1, 0),
                                              (5.0, 0), (1.0, 999)]):
            HeartbeatMonitor(run, host_id=host).beat(10, step_t)
            if age:  # backdate host 3 => dead
                p = Path(run) / "heartbeats" / f"host{host:04d}.json"
                d = json.loads(p.read_text())
                d["t"] = now - age
                p.write_text(json.dumps(d))
        rep = StragglerDetector(run, dead_after_s=120,
                                straggler_factor=2.0).assess(now=now)
        assert rep["dead"] == [3]
        assert rep["stragglers"] == [2]
        assert sorted(rep["healthy"]) == [0, 1]

    def test_empty_fleet(self, tmp_path):
        rep = StragglerDetector(str(tmp_path)).assess()
        assert rep["healthy"] == [] and rep["median_step_s"] is None
        assert rep["skewed"] == []

    def test_clock_skew_flagged_not_alive(self, tmp_path):
        """A heartbeat stamped in the future is a broken clock: the host is
        reported "skewed" — excluded from healthy (its liveness cannot be
        assessed) but also not "dead" (we have no evidence of death), and
        its step time does not pollute the fleet median."""
        run = str(tmp_path)
        now = time.time()
        for host, (step_t, skew) in enumerate([(1.0, 0), (1.2, 0),
                                               (50.0, 900)]):
            HeartbeatMonitor(run, host_id=host).beat(10, step_t)
            if skew:  # host 2's clock runs 15 minutes ahead
                p = Path(run) / "heartbeats" / f"host{host:04d}.json"
                d = json.loads(p.read_text())
                d["t"] = now + skew
                p.write_text(json.dumps(d))
        rep = StragglerDetector(run, dead_after_s=120,
                                skew_tolerance_s=5.0).assess(now=now)
        assert rep["skewed"] == [2]
        assert rep["dead"] == [] and sorted(rep["healthy"]) == [0, 1]
        # host 2's 50s step time is excluded from the median
        assert rep["median_step_s"] == pytest.approx(1.1)
