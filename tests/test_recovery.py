"""Supervised engine recovery (v1.5): crash-restart, deterministic
replay, suspect blacklisting, the hung-step watchdog, and the
crash-loop circuit breaker.

The keystone assertion, inherited from the determinism contract: a
request replayed onto a rebuilt engine regenerates from token 0 and the
handle's delivered-token cursor dedups the already-streamed prefix, so
the client-visible stream across any number of engine generations is
bit-identical to a crash-free run — no duplicate, no gap."""

import json
import os
import signal
import subprocess
import sys
import threading
import time
import urllib.error
import urllib.request
from pathlib import Path

import jax
import pytest

from repro import configs
from repro.models import init_params
from repro.runtime.monitor import (HeartbeatMonitor, HealthSnapshot,
                                   StragglerDetector)
from repro.serving import (EngineConfig, FaultInjector, FaultPlan,
                           SamplingParams, ServingEngine, VirtualClock)
from repro.serving.frontend import (DegradedError, EngineDriver,
                                    EngineSupervisor, StepTimeout,
                                    ThreadedHttpServer)

ROOT = Path(__file__).resolve().parents[1]

pytestmark = pytest.mark.timeout(300)  # a wedged recovery must fail fast

ECFG = dict(max_slots=2, capacity=64, decode_chunk=2, prefill_chunk=16)


def _wait_until(pred, timeout=60.0):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if pred():
            return True
        time.sleep(0.005)
    return False


@pytest.fixture(scope="module")
def small_model():
    cfg = configs.get_smoke_config("qwen2-1.5b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _oracle(small_model, jobs):
    """Crash-free reference streams for [(prompt, SamplingParams), ...]."""
    cfg, params = small_model
    eng = ServingEngine(params, cfg, EngineConfig(**ECFG))
    hs = [eng.submit(p, sp) for p, sp in jobs]
    eng.run()
    return [tuple(h.output) for h in hs]


def _supervisor(small_model, plans, clocks=None, **kw):
    """Supervisor whose factory arms ``plans[g]`` on generation g (clean
    past the end of the list). ``clocks[g]`` likewise pins a VirtualClock
    per generation. Injectors are recorded on the returned supervisor as
    ``._injectors`` so tests can release stalls in teardown."""
    cfg, params = small_model
    built = {"n": 0}
    injectors = []

    def factory():
        g = built["n"]
        built["n"] += 1
        plan = plans[g] if g < len(plans) else FaultPlan()
        clock = clocks[g] if clocks is not None and g < len(clocks) else None
        inj = FaultInjector(plan, clock=clock)
        injectors.append(inj)
        return ServingEngine(params, cfg, EngineConfig(**ECFG), injector=inj)

    kw.setdefault("restart_backoff_s", 0.01)
    sup = EngineSupervisor(factory, **kw)
    sup._injectors = injectors
    return sup.start()


def _post(base, obj, path="/v1/completions", method="POST"):
    data = json.dumps(obj).encode() if obj is not None else None
    req = urllib.request.Request(base + path, data=data, method=method,
                                 headers={"Content-Type": "application/json"})
    try:
        with urllib.request.urlopen(req, timeout=120) as resp:
            return resp.status, dict(resp.headers), json.loads(resp.read())
    except urllib.error.HTTPError as e:
        return e.code, dict(e.headers), json.loads(e.read())


def _sse(base, obj):
    req = urllib.request.Request(base + "/v1/completions",
                                 data=json.dumps(obj).encode(),
                                 headers={"Content-Type": "application/json"})
    tokens, result = [], None
    with urllib.request.urlopen(req, timeout=120) as resp:
        for raw in resp:
            line = raw.decode().strip()
            if not line.startswith("data: ") or line == "data: [DONE]":
                continue
            ev = json.loads(line[len("data: "):])
            if "token" in ev:
                tokens.append(ev["token"])
            else:
                result = ev
    return tokens, result


# ---------------------------------------------------------------------------
# crash → rebuild → replay, bit-identical
# ---------------------------------------------------------------------------

class TestCrashReplay:
    def test_crash_mid_decode_replays_bit_identical(self, small_model):
        """Ambiguous mid-decode crash: both residents replay from token 0
        on the rebuilt engine; the spliced streams equal the crash-free
        oracle and every token index is delivered exactly once."""
        jobs = [([5, 9, 17, 2], SamplingParams(max_new_tokens=8, seed=0)),
                ([1, 2, 3], SamplingParams(max_new_tokens=8, seed=1))]
        ref = _oracle(small_model, jobs)
        sup = _supervisor(small_model,
                          [FaultPlan().engine_crash("decode", 2)],
                          blacklist_after=9)
        try:
            events = [[], []]
            handles = []
            for i, (p, sp) in enumerate(jobs):
                h = sup.submit(p, sp)
                h.subscribe(events[i].append)
                handles.append(h)
            results = [h.result(timeout=120) for h in handles]
            assert [r.finish_reason for r in results] == ["length", "length"]
            assert [tuple(r.tokens) for r in results] == ref
            # delivered exactly once, in order, across the generation swap
            for i, evs in enumerate(events):
                toks = [e for e in evs if e[0] == "token"]
                assert [e[1] for e in toks] == list(range(8))
                assert tuple(e[2] for e in toks) == ref[i]
            assert sup.generation == 1 and sup.restarts == 1
            assert sup.replayed == 2 and not sup.blacklist
            st = sup.stats()
            assert st["retired"] == 2 and st["generation"] == 1
        finally:
            sup.close()

    def test_single_suspect_retires_error_exactly_once(self, small_model):
        """A crash blamed on one resident uid blacklists it immediately:
        it retires "error" exactly once, carrying the crash detail, while
        its co-resident replays bit-identical."""
        jobs = [([5, 9, 17, 2], SamplingParams(max_new_tokens=16, seed=3)),
                ([1, 2, 3], SamplingParams(max_new_tokens=8, seed=4))]
        ref = _oracle(small_model, jobs)
        sup = _supervisor(small_model,
                          [FaultPlan().engine_crash("decode", 1, uid=0)])
        try:
            suspect = sup.submit(*jobs[0])
            victim = sup.submit(*jobs[1])
            assert (suspect.uid, victim.uid) == (0, 1)
            res_s = suspect.result(timeout=120)
            res_v = victim.result(timeout=120)
            assert res_s.finish_reason == "error"
            assert "engine died (generation 0)" in res_s.error
            assert "EngineCrash" in res_s.error
            assert "blacklisted as crash suspect" in res_s.error
            assert suspect.error == res_s.error  # handle carries the detail
            assert res_v.finish_reason == "length"
            assert tuple(res_v.tokens) == ref[1]
            assert sup.blacklist == {0}
            assert [r.uid for r in sup.results()].count(0) == 1  # once
            assert sup.replayed == 1
        finally:
            sup.close()

    def test_poison_request_blacklisted_on_second_strike(self, small_model):
        """Two ambiguous crashes with the same request resident: the
        repeat offender reaches blacklist_after strikes and is condemned;
        its neighbor (one strike, finished before the second crash)
        completes bit-identical."""
        poison = ([5, 9, 17, 2], SamplingParams(max_new_tokens=32, seed=5))
        victim = ([1, 2, 3], SamplingParams(max_new_tokens=4, seed=6))
        ref = _oracle(small_model, [poison, victim])
        # gen0: crash at decode #1 — both resident, ambiguous (1 strike
        # each). gen1: victim (4 tokens, decode_chunk=2) finishes by
        # decode #1; crash at #4 catches the poison alone → strike 2.
        sup = _supervisor(small_model,
                          [FaultPlan().engine_crash("decode", 1),
                           FaultPlan().engine_crash("decode", 4)],
                          blacklist_after=2)
        try:
            hp = sup.submit(*poison)
            hv = sup.submit(*victim)
            res_p = hp.result(timeout=120)
            res_v = hv.result(timeout=120)
            assert res_v.finish_reason == "length"
            assert tuple(res_v.tokens) == ref[1]
            assert res_p.finish_reason == "error"
            assert "strike 2" in res_p.error
            assert sup.blacklist == {hp.uid}
            assert sup.crash_counts[hp.uid] == 2
            # the rebuild (generation 2) lands just after the suspect's
            # retirement; the crash loop has converged and the engine idles
            assert _wait_until(lambda: sup.generation == 2)
            assert not sup.degraded
        finally:
            sup.close()

    def test_crash_before_first_token_replays_clean(self, small_model):
        """Crash at decode dispatch #0: nothing delivered yet, replay is a
        from-scratch run — the degenerate dedup case (cursor at 0)."""
        jobs = [([5, 9], SamplingParams(max_new_tokens=6, seed=7)),
                ([1, 2, 3], SamplingParams(max_new_tokens=6, seed=8))]
        ref = _oracle(small_model, jobs)
        sup = _supervisor(small_model,
                          [FaultPlan().engine_crash("decode", 0)],
                          blacklist_after=9)
        try:
            hs = [sup.submit(p, sp) for p, sp in jobs]
            assert [tuple(h.result(timeout=120).tokens) for h in hs] == ref
            assert sup.generation == 1
        finally:
            sup.close()

    def test_crash_mid_prefill_dedups_decoding_survivor(self, small_model):
        """Crash during a chunked prefill: the prefilling row is the sole
        suspect (blacklisted, "error"); the co-resident row — already
        streaming — replays with its delivered prefix deduped."""
        cfg, params = small_model
        long_prompt = list(range(1, 40))  # > prefill_chunk → chunked
        jobs = [([5, 9, 17, 2], SamplingParams(max_new_tokens=12, seed=9))]
        ref = _oracle(small_model, jobs)
        sup = _supervisor(small_model,
                          [FaultPlan().engine_crash("prefill", 3)])
        try:
            survivor = sup.submit(*jobs[0])
            # let the survivor get tokens on the wire before the suspect
            # prompt starts prefilling (its chunked prefill then crashes)
            assert _wait_until(lambda: len(survivor.output) >= 2)
            suspect = sup.submit(long_prompt,
                                 SamplingParams(max_new_tokens=12, seed=10))
            res_s = suspect.result(timeout=120)
            res_v = survivor.result(timeout=120)
            assert res_s.finish_reason == "error"
            assert "blacklisted" in res_s.error
            assert res_v.finish_reason == "length"
            assert tuple(res_v.tokens) == ref[0]
            assert sup.blacklist == {suspect.uid}
        finally:
            sup.close()


# ---------------------------------------------------------------------------
# SSE continuity across a crash (the wire-level dedup assertion)
# ---------------------------------------------------------------------------

class TestHttpRecovery:
    def test_sse_stream_continues_across_crash(self, small_model):
        jobs = [([5, 9, 17, 2], SamplingParams(max_new_tokens=10, seed=0)),
                ([1, 2, 3], SamplingParams(max_new_tokens=10, seed=1))]
        ref = _oracle(small_model, jobs)
        sup = _supervisor(small_model,
                          [FaultPlan().engine_crash("decode", 2)],
                          blacklist_after=9)
        srv = ThreadedHttpServer(sup).start()
        base = f"http://{srv.host}:{srv.port}"
        try:
            outs = [None, None]

            def fire(i):
                p, sp = jobs[i]
                outs[i] = _sse(base, {
                    "prompt": list(p), "stream": True,
                    "max_new_tokens": sp.max_new_tokens, "seed": sp.seed})

            ths = [threading.Thread(target=fire, args=(i,)) for i in (0, 1)]
            for th in ths:
                th.start()
            for th in ths:
                th.join(timeout=120)
            assert all(o is not None for o in outs)
            for i, (tokens, result) in enumerate(outs):
                assert result["finish_reason"] == "length"
                assert tuple(tokens) == ref[i]  # no dup, no gap, no drift
            assert sup.generation == 1
        finally:
            srv.stop()
            sup.close()

    def test_unsupervised_crash_maps_to_500_with_detail(self, small_model):
        """Without a supervisor the driver retires everything "error" and
        the HTTP layer maps it to 500 — the body carries the exception
        detail so a client can tell engine death from a request fault."""
        cfg, params = small_model
        eng = ServingEngine(
            params, cfg, EngineConfig(**ECFG),
            injector=FaultInjector(FaultPlan().engine_crash("decode", 0)))
        driver = EngineDriver(eng).start()
        srv = ThreadedHttpServer(driver).start()
        base = f"http://{srv.host}:{srv.port}"
        try:
            status, _h, body = _post(base, {"prompt": [1, 2, 3],
                                            "max_new_tokens": 4})
            assert status == 500
            assert "engine died (generation 0)" in body["error"]
            assert "EngineCrash" in body["error"]
        finally:
            srv.stop()
            driver.close()

    def test_degraded_sheds_503_with_retry_after(self, small_model):
        """Breaker open: new submits shed 503 + Retry-After while the
        supervisor keeps converging; /healthz reports the state."""
        sup = _supervisor(small_model,
                          [FaultPlan().engine_crash("decode", 1),
                           FaultPlan().engine_crash("decode", 0)],
                          max_restarts=2, crash_window_s=300.0,
                          retry_after_s=7.0, blacklist_after=9)
        srv = ThreadedHttpServer(sup).start()
        base = f"http://{srv.host}:{srv.port}"
        try:
            # two co-residents: both crashes attribute ambiguously, so the
            # work replays through both and lands on generation 2 — while
            # the second crash inside the window opens the breaker
            hs = [sup.submit([5, 9, 17], SamplingParams(max_new_tokens=8,
                                                        seed=0)),
                  sup.submit([1, 2, 3], SamplingParams(max_new_tokens=8,
                                                       seed=1))]
            for h in hs:
                assert h.result(timeout=120).finish_reason == "length"
            assert _wait_until(lambda: sup.degraded)
            assert sup.restarts == 2  # breaker capped the rebuild count
            status, headers, body = _post(base, {"prompt": [1, 2],
                                                 "max_new_tokens": 2})
            assert status == 503
            assert headers.get("Retry-After") == "7"
            assert body["degraded"] is True
            assert "degraded" in body["error"]
            status, _h, health = _post(base, None, path="/healthz",
                                       method="GET")
            assert health["supervisor"]["degraded"] is True
            assert health["supervisor"]["restarts"] == 2
        finally:
            srv.stop()
            sup.close()


# ---------------------------------------------------------------------------
# watchdog: a hung step is a crash
# ---------------------------------------------------------------------------

class TestWatchdog:
    def test_hung_step_recovers_and_replays(self, small_model):
        """stall_step wedges the driver thread inside engine.step() after
        advancing the (virtual) engine clock past the watchdog budget:
        the supervisor reaps the wedged driver, rebuilds, and replays;
        when the stalled thread finally wakes it finds itself abandoned
        and exits without touching the migrated handles."""
        jobs = [([5, 9, 17, 2], SamplingParams(max_new_tokens=8, seed=11)),
                ([1, 2, 3], SamplingParams(max_new_tokens=8, seed=12))]
        ref = _oracle(small_model, jobs)
        clock = VirtualClock()
        sup = _supervisor(small_model,
                          [FaultPlan().stall_step(at_step=3, hang_s=60.0)],
                          clocks=[clock],
                          watchdog_step_timeout_s=5.0,
                          blacklist_after=9)
        try:
            hs = [sup.submit(p, sp) for p, sp in jobs]
            inj = sup._injectors[0]
            assert inj.stall_engaged.wait(timeout=60)
            assert _wait_until(lambda: sup.generation == 1)
            rec = sup.recoveries[0]
            assert rec["exc"].startswith("StepTimeout")
            inj.release_stalls()  # the wedged gen-0 thread wakes, exits
            assert [tuple(h.result(timeout=120).tokens) for h in hs] == ref
            assert sup.replayed == 2
            # the woken thread must not have double-delivered anything
            assert all(len(h.output) == 8 for h in hs)
        finally:
            for inj in sup._injectors:
                inj.release_stalls()
            sup.close()


# ---------------------------------------------------------------------------
# breaker lifecycle + terminal factory failure
# ---------------------------------------------------------------------------

class TestBreaker:
    def test_breaker_closes_after_quiet_window(self, small_model):
        sup = _supervisor(small_model,
                          [FaultPlan().engine_crash("decode", 0)],
                          max_restarts=1, crash_window_s=0.2,
                          blacklist_after=9)
        try:
            # two co-residents: the crash attributes ambiguously, so both
            # replay (a lone resident would be condemned as sole suspect)
            hs = [sup.submit([1, 2, 3], SamplingParams(max_new_tokens=4,
                                                       seed=0)),
                  sup.submit([4, 5], SamplingParams(max_new_tokens=4,
                                                    seed=1))]
            for h in hs:
                assert h.result(timeout=120).finish_reason == "length"
            assert _wait_until(lambda: sup.restarts == 1)
            # opened by the crash, closed by a crash-free window
            assert _wait_until(lambda: not sup.degraded)
            h2 = sup.submit([4, 5], SamplingParams(max_new_tokens=2, seed=1))
            assert h2.result(timeout=120).finish_reason == "length"
        finally:
            sup.close()

    def test_factory_failure_is_terminal(self, small_model):
        cfg, params = small_model
        built = {"n": 0}

        def factory():
            if built["n"] >= 1:
                raise RuntimeError("no artifact to rebuild from")
            built["n"] += 1
            return ServingEngine(
                params, cfg, EngineConfig(**ECFG),
                injector=FaultInjector(
                    FaultPlan().engine_crash("decode", 0)))

        sup = EngineSupervisor(factory, restart_backoff_s=0.01).start()
        try:
            h = sup.submit([1, 2, 3], SamplingParams(max_new_tokens=4))
            res = h.result(timeout=120)
            assert res.finish_reason == "error"
            assert _wait_until(lambda: sup.dead)
            with pytest.raises(DegradedError, match="permanently failed"):
                sup.submit([4, 5], SamplingParams(max_new_tokens=2))
            assert sup.supervisor_status()["dead"] is True
        finally:
            sup.close()


# ---------------------------------------------------------------------------
# drain/close vs crash races
# ---------------------------------------------------------------------------

class TestShutdownRaces:
    def test_drain_racing_a_crash_never_hangs(self, small_model):
        jobs = [([5, 9, 17, 2], SamplingParams(max_new_tokens=8, seed=13)),
                ([1, 2, 3], SamplingParams(max_new_tokens=8, seed=14))]
        ref = _oracle(small_model, jobs)
        sup = _supervisor(small_model,
                          [FaultPlan().engine_crash("decode", 1)],
                          blacklist_after=9)
        try:
            hs = [sup.submit(p, sp) for p, sp in jobs]
            # wait until both requests are resident (a drain would shed
            # fair-queue waiters with "rejected"), then drain while the
            # crash is (about to be) in flight: reap sets the old driver's
            # drained event, so this returns rather than deadlocking; the
            # replay then finishes on the new generation
            assert _wait_until(lambda: all(h._delivered > 0 for h in hs))
            assert sup.drain(timeout=60.0)
            assert [tuple(h.result(timeout=120).tokens) for h in hs] == ref
        finally:
            sup.close()

    def test_close_is_idempotent_after_crash(self, small_model):
        sup = _supervisor(small_model,
                          [FaultPlan().engine_crash("decode", 0)],
                          blacklist_after=9)
        hs = [sup.submit([1, 2, 3], SamplingParams(max_new_tokens=4,
                                                   seed=0)),
              sup.submit([4, 5], SamplingParams(max_new_tokens=4, seed=1))]
        for h in hs:
            assert h.result(timeout=120).finish_reason == "length"
        sup.close()
        sup.close()  # second close is a no-op, not an error

    def test_unsupervised_driver_close_after_fatal(self, small_model):
        """Standalone driver: _fatal retires everything with the crash
        detail; drain() and double close() afterwards are no-ops."""
        cfg, params = small_model
        eng = ServingEngine(
            params, cfg, EngineConfig(**ECFG),
            injector=FaultInjector(FaultPlan().engine_crash("decode", 0)))
        driver = EngineDriver(eng).start()
        h = driver.submit([1, 2, 3], SamplingParams(max_new_tokens=4))
        res = h.result(timeout=120)
        assert res.finish_reason == "error"
        assert "engine died (generation 0)" in res.error
        assert h.error == res.error
        assert driver.fatal_exc is not None
        assert driver.drain(timeout=10.0)
        driver.close()
        driver.close()


# ---------------------------------------------------------------------------
# heartbeat schema 3: generation + restarts ride the fleet protocol
# ---------------------------------------------------------------------------

class TestHeartbeat:
    def test_heartbeat_carries_generation_and_restarts(self, small_model,
                                                       tmp_path):
        sup = _supervisor(small_model,
                          [FaultPlan().engine_crash("decode", 0)],
                          blacklist_after=9)
        try:
            hs = [sup.submit([1, 2, 3], SamplingParams(max_new_tokens=4,
                                                       seed=0)),
                  sup.submit([4, 5], SamplingParams(max_new_tokens=4,
                                                    seed=1))]
            for h in hs:
                assert h.result(timeout=120).finish_reason == "length"
            digest = sup.call(lambda eng: eng.obs.digest())
            assert digest["engine_generation"] == 1
            assert digest["engine_restarts"] == 1
            snap = sup.call(lambda eng: eng.health())
            snap.beat(HeartbeatMonitor(str(tmp_path)), metrics=digest)
        finally:
            sup.close()
        beats = StragglerDetector(str(tmp_path)).read()
        assert beats[0]["engine_generation"] == 1
        assert beats[0]["engine_restarts"] == 1

    def test_detector_tolerates_pre_supervision_payloads(self, tmp_path):
        d = tmp_path / "heartbeats"
        d.mkdir()
        (d / "host0000.json").write_text(json.dumps(
            {"host": 0, "t": 1.0, "step": 3}))  # v1: no supervision keys
        beats = StragglerDetector(str(tmp_path)).read()
        assert beats[0]["engine_generation"] == 0
        assert beats[0]["engine_restarts"] == 0


# ---------------------------------------------------------------------------
# serve.py: flag validation + second-signal force quit (subprocess)
# ---------------------------------------------------------------------------

def test_serve_supervise_requires_http():
    from repro.launch import serve
    with pytest.raises(SystemExit):
        serve.main(["--supervise"])


@pytest.mark.slow
def test_serve_second_sigint_force_quits_nonzero(tmp_path):
    """First SIGINT drains gracefully (rc 0, covered elsewhere); a second
    one force-quits immediately with rc 128+SIGINT = 130, so a process
    manager can tell a forced kill from a clean shutdown."""
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.serve", "--no-quantize",
         "--requests", "8", "--max-new", "500", "--slots", "2"],
        cwd=ROOT, stdout=subprocess.PIPE, stderr=subprocess.STDOUT,
        text=True, env={**os.environ, "PYTHONPATH": str(ROOT / "src"),
                        "PYTHONUNBUFFERED": "1"})
    try:
        booted = False
        for line in proc.stdout:
            if line.startswith("[serve] boot"):
                booted = True
                break
        assert booted, "serve.py never finished booting"
        proc.send_signal(signal.SIGINT)
        forced = False
        for line in proc.stdout:
            if "draining" in line:          # first signal acknowledged,
                proc.send_signal(signal.SIGINT)  # now really mean it
            if "force quit" in line:
                forced = True
                break
        rc = proc.wait(timeout=60)
    finally:
        if proc.poll() is None:
            proc.kill()
    assert forced, "second signal never hit the force-quit handler"
    assert rc == 128 + signal.SIGINT, rc
