"""Core PTQTP quantizer: paper Alg. 1/2 invariants, unit + property tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:  # property tests need hypothesis; the rest of the module runs without
    import hypothesis
    import hypothesis.extra.numpy as hnp
    import hypothesis.strategies as st
except ImportError:
    hypothesis = None

from repro.core.ptqtp import (CANDIDATES, PTQTPConfig, ptqtp_dequantize,
                              ptqtp_error, ptqtp_quantize,
                              quantize_with_history)

jax.config.update("jax_enable_x64", False)


def _randw(shape, seed=0, scale=1.0):
    return jnp.asarray(
        np.random.default_rng(seed).standard_normal(shape, dtype=np.float32)
        * scale)


# ---------------------------------------------------------------------------
# unit
# ---------------------------------------------------------------------------

class TestBasics:
    def test_shapes_and_ternary_domain(self):
        w = _randw((16, 256))
        q = ptqtp_quantize(w, PTQTPConfig(group_size=128, t_max=10))
        assert q.t1.shape == w.shape and q.t2.shape == w.shape
        assert q.alpha.shape == (16, 2, 2)  # (n, d//G, 2)
        for t in (q.t1, q.t2):
            vals = np.unique(np.asarray(t))
            assert set(vals.tolist()) <= {-1, 0, 1}

    def test_reconstruction_beats_sign_init(self):
        """Progressive optimization must improve on the α=[1,1]·sign init."""
        w = _randw((8, 256))
        q = ptqtp_quantize(w, PTQTPConfig(t_max=30))
        err = float(ptqtp_error(w, q))
        sgn = jnp.sign(w) + (w == 0)
        init_err = float(jnp.linalg.norm(w - 2 * sgn) / jnp.linalg.norm(w))
        assert err < init_err
        assert err < 0.5  # gaussian weights: two planes explain most mass

    def test_two_planes_beat_one_plane(self):
        """The 2nd trit-plane must add representational power (paper's core
        claim vs binary/ternary-1-plane PTQ)."""
        w = _randw((8, 256))
        q2 = ptqtp_quantize(w, PTQTPConfig(t_max=30))
        # best rank-1 ternary plane w/ optimal per-group scale (RTN-ternary)
        wg = np.asarray(w).reshape(-1, 128)
        t = np.sign(wg) * (np.abs(wg) > 0.6745 * np.abs(wg).mean(-1, keepdims=True))
        num = (t * wg).sum(-1)
        den = np.maximum((t * t).sum(-1), 1e-9)
        a = num / den
        err1 = np.linalg.norm(wg - a[:, None] * t) / np.linalg.norm(wg)
        assert float(ptqtp_error(w, q2)) < err1

    def test_group_wise_beats_row_wise_on_heterogeneous_weights(self):
        """G=128 grouping beats one α pair per whole row when weight scale
        varies across the row (paper Table 8). Real LLM rows are
        heterogeneous — grouping exploits that locality; on iid Gaussian
        weights the effect vanishes, so the test builds LLM-like rows with
        per-group scale variation."""
        eg, er = [], []
        for seed in range(3):
            r = np.random.default_rng(seed)
            base = r.standard_normal((8, 512), dtype=np.float32)
            scales = np.exp(r.normal(0, 1.2, size=(1, 4)).astype(np.float32))
            w = jnp.asarray((base.reshape(8, 4, 128)
                             * scales[..., None]).reshape(8, 512))
            qg = ptqtp_quantize(w, PTQTPConfig(group_size=128, t_max=30))
            qr = ptqtp_quantize(w, PTQTPConfig(group_size=512, t_max=30))
            eg.append(float(ptqtp_error(w, qg)))
            er.append(float(ptqtp_error(w, qr)))
        assert np.mean(eg) < np.mean(er), (eg, er)

    def test_convergence_within_tmax(self):
        w = _randw((8, 256))
        q = ptqtp_quantize(w, PTQTPConfig(t_max=50, eps=1e-4))
        assert int(q.iters) <= 50  # paper: "always converges within 50"

    def test_dequantize_matches_planes(self):
        w = _randw((4, 256))
        q = ptqtp_quantize(w, PTQTPConfig(t_max=5))
        what = ptqtp_dequantize(q)
        n, d = w.shape
        g = q.group_size
        t1 = np.asarray(q.t1, np.float32).reshape(n, d // g, g)
        t2 = np.asarray(q.t2, np.float32).reshape(n, d // g, g)
        a = np.asarray(q.alpha, np.float32)
        manual = (t1 * a[..., :1] + t2 * a[..., 1:]).reshape(n, d)
        np.testing.assert_allclose(np.asarray(what), manual, rtol=1e-6)

    def test_bad_shapes_rejected(self):
        with pytest.raises(ValueError):
            ptqtp_quantize(_randw((4, 100)), PTQTPConfig(group_size=128))
        with pytest.raises(ValueError):
            ptqtp_quantize(_randw((4, 4, 128)))

    def test_candidates_cover_all_nine(self):
        assert CANDIDATES.shape == (9, 2)
        assert len({tuple(c) for c in CANDIDATES.tolist()}) == 9


# ---------------------------------------------------------------------------
# paper-claim properties
# ---------------------------------------------------------------------------

class TestPaperClaims:
    def test_error_monotonically_non_increasing(self):
        """App. C: each iteration must not increase ||W - Ŵ||_F."""
        w = _randw((8, 256), seed=3)
        _, errors = quantize_with_history(w, PTQTPConfig(t_max=30))
        e = np.asarray(errors)
        assert np.all(e[1:] <= e[:-1] + 1e-4 * e[0]), e

    def test_tighter_eps_not_worse(self):
        """Fig. 4: tighter tolerance → equal-or-better reconstruction."""
        w = _randw((8, 256), seed=4)
        e_loose = float(ptqtp_error(w, ptqtp_quantize(
            w, PTQTPConfig(t_max=50, eps=1e-1))))
        e_tight = float(ptqtp_error(w, ptqtp_quantize(
            w, PTQTPConfig(t_max=50, eps=1e-5))))
        assert e_tight <= e_loose + 1e-6

    def test_more_iterations_not_worse(self):
        """Fig. 3: more progressive iterations → equal-or-better error."""
        w = _randw((8, 256), seed=5)
        e1 = float(ptqtp_error(w, ptqtp_quantize(w, PTQTPConfig(t_max=1))))
        e30 = float(ptqtp_error(w, ptqtp_quantize(w, PTQTPConfig(t_max=30))))
        assert e30 <= e1 + 1e-6

    def test_outlier_robustness(self):
        """§D.1: group-wise localizes outliers — error stays bounded when one
        group carries a 100× outlier."""
        w = np.asarray(_randw((4, 512), seed=6)).copy()
        w[0, 5] = 100.0
        q = ptqtp_quantize(jnp.asarray(w), PTQTPConfig(t_max=30))
        werr = np.asarray(ptqtp_dequantize(q)) - w
        # groups that do NOT contain the outlier are unaffected
        clean = np.linalg.norm(werr[:, 128:]) / np.linalg.norm(w[:, 128:])
        assert clean < 0.5

    def test_lambda_adaptation_stabilizes_degenerate_rows(self):
        """Eq. 3: a constant row makes S rank-1 (t1 == t2) — the adaptive λ
        must keep α finite and the approximation sane."""
        w = jnp.ones((2, 256), jnp.float32) * 0.7
        q = ptqtp_quantize(w, PTQTPConfig(t_max=20))
        assert np.all(np.isfinite(np.asarray(q.alpha)))
        assert float(ptqtp_error(w, q)) < 0.05


# ---------------------------------------------------------------------------
# hypothesis properties (defined only when hypothesis is installed)
# ---------------------------------------------------------------------------

if hypothesis is not None:
    w_strat = hnp.arrays(
        np.float32, st.tuples(st.integers(1, 4), st.just(128)),
        elements=st.floats(-4, 4, width=32, allow_nan=False),
    )

    class TestHypothesis:
        @hypothesis.given(w=w_strat)
        @hypothesis.settings(max_examples=25, deadline=None)
        def test_error_never_exceeds_norm(self, w):
            """α=0 is in the feasible set, so ||W-Ŵ|| ≤ ~||W||."""
            q = ptqtp_quantize(jnp.asarray(w), PTQTPConfig(group_size=128,
                                                           t_max=10))
            err = np.linalg.norm(np.asarray(ptqtp_dequantize(q)) - w)
            assert err <= np.linalg.norm(w) * (1 + 1e-3) + 1e-3

        @hypothesis.given(w=w_strat, c=st.floats(0.125, 8.0, width=32))
        @hypothesis.settings(max_examples=15, deadline=None)
        def test_positive_scale_equivariance(self, w, c):
            """err(ptqtp(c·W)) ≈ c·err(ptqtp(W)) for c > 0. The *error* is the
            scale-covariant quantity; elementwise trits may differ — an element
            sitting exactly on an argmin tie can flip when scaling moves fp
            rounding across the boundary (observed via hypothesis)."""
            hypothesis.assume(np.linalg.norm(w) > 1e-2)
            q1 = ptqtp_quantize(jnp.asarray(w), PTQTPConfig(t_max=10))
            q2 = ptqtp_quantize(jnp.asarray(w * c), PTQTPConfig(t_max=10))
            e1 = np.linalg.norm(w * c - np.asarray(ptqtp_dequantize(q1)) * c)
            e2 = np.linalg.norm(w * c - np.asarray(ptqtp_dequantize(q2)))
            tol = 5e-2 * c * (np.linalg.norm(w) + 1e-3)
            assert abs(e1 - e2) <= tol, (e1, e2, tol)

        @hypothesis.given(w=w_strat)
        @hypothesis.settings(max_examples=15, deadline=None)
        def test_monotone_error_property(self, w):
            """Error is monotone up to the regularization bias: on degenerate
            inputs (constant rows / one dominant element + near-zero tail) the
            adaptive-λ refit trades a λ·‖α‖² bias for stability, so the
            unregularized error can tick up by a few percent of ‖W‖
            (hypothesis measured ≈2% worst-case); we bound the slack at
            5%·‖W‖."""
            hypothesis.assume(np.linalg.norm(w) > 1e-3)
            _, errors = quantize_with_history(jnp.asarray(w),
                                              PTQTPConfig(t_max=10))
            e = np.asarray(errors)
            tol = 5e-2 * (np.linalg.norm(w) + 1e-6)
            assert np.all(e[1:] <= e[:-1] + tol)
