"""HTTP serving frontend (v1.4): EngineDriver thread-safety, DRR fair
admission, the asyncio SSE endpoint, and serve.py's graceful shutdown.

The keystone assertion, inherited from the determinism contract: outputs
are a pure function of (params, prompt, SamplingParams), so tokens
through the driver — from any number of threads, over any socket — are
bit-identical to cooperative ``engine.submit``."""

import json
import os
import signal
import socket
import subprocess
import sys
import threading
import time
import types
import urllib.error
import urllib.request
from pathlib import Path

import jax
import pytest

from repro import configs
from repro.models import init_params
from repro.serving import (EngineConfig, FINISH_REASONS, FaultInjector,
                           FaultPlan, SamplingParams, ServingEngine,
                           VirtualClock)
from repro.serving.frontend import (EngineDriver, FairScheduler,
                                    ThreadedHttpServer)

ROOT = Path(__file__).resolve().parents[1]

pytestmark = pytest.mark.timeout(300)  # a deadlocked driver must fail fast


def _wait_until(pred, timeout=60.0):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if pred():
            return True
        time.sleep(0.005)
    return False


@pytest.fixture(scope="module")
def small_model():
    cfg = configs.get_smoke_config("qwen2-1.5b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _engine(small_model, ecfg=None, plan=None, clock=None):
    cfg, params = small_model
    inj = FaultInjector(plan, clock=clock) \
        if (plan is not None or clock is not None) else None
    return ServingEngine(params, cfg,
                         ecfg or EngineConfig(max_slots=2, capacity=64),
                         injector=inj)


# ---------------------------------------------------------------------------
# FairScheduler: DRR order, weights, caps, no credit banking
# ---------------------------------------------------------------------------

def _req(tenant="", cost=10):
    return types.SimpleNamespace(params=types.SimpleNamespace(tenant=tenant),
                                 cost=cost)


def _fs(**kw):
    kw.setdefault("cost", lambda h: h.cost)
    return FairScheduler(**kw)


class TestFairScheduler:
    def test_single_tenant_is_fifo(self):
        fs = _fs(quantum=100)
        hs = [_req() for _ in range(5)]
        for h in hs:
            assert fs.push(h) is None
        assert [fs.pop() for _ in range(5)] == hs
        assert fs.pop() is None and len(fs) == 0

    def test_drr_alternates_between_backlogged_tenants(self):
        """quantum = 2 requests' worth → each visit serves a run of two,
        then the turn ends: AABB AABB, never an unbounded run (the front
        tenant must not replenish more than once per ring visit)."""
        fs = _fs(quantum=20)
        for _ in range(6):
            fs.push(_req("A", 10))
            fs.push(_req("B", 10))
        order = [fs.pop().params.tenant for _ in range(8)]
        assert order == ["A", "A", "B", "B", "A", "A", "B", "B"]

    def test_weights_scale_bandwidth(self):
        fs = _fs(quantum=10, weights={"A": 2.0})
        for _ in range(6):
            fs.push(_req("A", 10))
            fs.push(_req("B", 10))
        order = [fs.pop().params.tenant for _ in range(6)]
        assert order == ["A", "A", "B", "A", "A", "B"]

    def test_empty_queue_forfeits_deficit(self):
        """A tenant that drains loses its credit — idling must not bank
        bandwidth for a later burst."""
        fs = _fs(quantum=100)
        fs.push(_req("A", 10))
        a = fs.pop()
        assert fs._tenants["A"].deficit == 0.0  # reset on empty, not 90
        fs.retire(a)
        assert "A" not in fs._tenants  # fully idle tenants are dropped

    def test_resident_token_cap_blocks_then_frees(self):
        fs = _fs(quantum=100, tenant_max_resident_tokens=25)
        hs = [_req("A", 10) for _ in range(4)]
        for h in hs:
            fs.push(h)
        served = [fs.pop(), fs.pop()]
        assert served == hs[:2]
        assert fs.pop() is None            # 20 + 10 > 25: capped
        assert fs.inflight_by_tenant() == {"A": 20}
        fs.retire(served[0])               # room frees...
        assert fs.pop() is hs[2]           # ...and the queue moves again

    def test_capped_tenant_does_not_starve_others(self):
        fs = _fs(quantum=100, tenant_max_resident_tokens=15)
        fs.push(_req("A", 10))
        fs.push(_req("A", 10))
        fs.push(_req("B", 10))
        assert fs.pop().params.tenant == "A"
        assert fs.pop().params.tenant == "B"  # A capped: skipped, no stall

    def test_max_pending_sheds(self):
        fs = _fs(max_pending=2)
        assert fs.push(_req()) is None and fs.push(_req()) is None
        why = fs.push(_req())
        assert why is not None and "full" in why

    def test_remove_and_drain(self):
        fs = _fs()
        a, b = _req("A"), _req("B")
        fs.push(a)
        fs.push(b)
        assert fs.remove(a) and not fs.remove(a)
        assert fs.drain() == [b] and len(fs) == 0


# ---------------------------------------------------------------------------
# EngineDriver: bit-identity, concurrency under faults, cancel/drain
# ---------------------------------------------------------------------------

PROMPTS = [[5, 9, 17, 2], [1, 2], [3, 4, 5], [7, 11, 13, 17, 19]]


def _coop_reference(small_model, reqs, ecfg=None):
    """Fault-free cooperative run of (prompt, SamplingParams) pairs →
    {(prompt, seed): tokens}. One engine: determinism makes co-batching
    irrelevant."""
    eng = _engine(small_model, ecfg)
    hs = [(p, sp, eng.submit(p, sp)) for p, sp in reqs]
    eng.run()
    return {(tuple(p), sp.seed): tuple(h.output) for p, sp, h in hs}


class TestEngineDriver:
    def test_bit_identical_to_cooperative(self, small_model):
        reqs = [(PROMPTS[i], SamplingParams(max_new_tokens=6, seed=i))
                for i in range(3)]
        reqs.append((PROMPTS[3], SamplingParams(max_new_tokens=6,
                                                temperature=0.9, seed=41)))
        ref = _coop_reference(small_model, reqs)
        driver = EngineDriver(_engine(small_model)).start()
        hs = [driver.submit(p, sp) for p, sp in reqs]
        streamed = list(hs[0].tokens())  # same-step queue consumption
        for (p, sp), h in zip(reqs, hs):
            res = h.result(timeout=120)
            assert res.finish_reason == "length"
            assert res.tokens == ref[(tuple(p), sp.seed)]
        assert tuple(streamed) == ref[(tuple(PROMPTS[0]), 0)]
        assert driver.drain(timeout=60)
        driver.close()

    def test_many_threads_with_faults_no_deadlock(self, small_model):
        """12 requests from 6 threads, each consuming its own stream,
        against an engine with a seeded NaN fault. Gates: every thread
        joins (no deadlock), every finish_reason is valid, the poisoned
        uid errors, and every survivor is bit-identical to a fault-free
        cooperative run."""
        n_threads, per_thread = 6, 2
        reqs = [(PROMPTS[i % len(PROMPTS)],
                 SamplingParams(max_new_tokens=8, temperature=0.9, seed=i))
                for i in range(n_threads * per_thread)]
        ref = _coop_reference(small_model, reqs)

        ecfg = EngineConfig(max_slots=2, capacity=64, quarantine_steps=None)
        plan = FaultPlan().nan_logits(uid=3, gen_index=1)
        driver = EngineDriver(_engine(small_model, ecfg, plan=plan)).start()

        out = {}

        def client(t):
            for j in range(per_thread):
                i = t * per_thread + j
                p, sp = reqs[i]
                h = driver.submit(p, sp)
                toks = list(h.tokens())      # stream to completion
                out[i] = (h, tuple(toks), h.result(timeout=0.0))

        threads = [threading.Thread(target=client, args=(t,))
                   for t in range(n_threads)]
        for th in threads:
            th.start()
        for th in threads:
            th.join(timeout=240)
        assert not any(th.is_alive() for th in threads), "driver deadlocked"

        assert sorted(out) == list(range(len(reqs)))
        victims = 0
        for i, (h, toks, res) in out.items():
            assert res.finish_reason in FINISH_REASONS
            assert toks == res.tokens  # stream delivered exactly the record
            if res.uid == 3:
                victims += 1
                assert res.finish_reason == "error"
                assert "non-finite logits" in res.error
            else:
                assert res.finish_reason == "length"
                p, sp = reqs[i]
                assert res.tokens == ref[(tuple(p), sp.seed)]
        assert victims == 1  # exactly the planned uid was poisoned
        driver.close()

    def test_cancel_queued_and_resident(self, small_model):
        ecfg = EngineConfig(max_slots=1, capacity=64)
        driver = EngineDriver(_engine(small_model, ecfg)).start()
        a = driver.submit(PROMPTS[0], SamplingParams(max_new_tokens=32))
        b = driver.submit(PROMPTS[1], SamplingParams(max_new_tokens=32))
        # a resident (the single slot), b still waiting in the fair queue
        assert _wait_until(lambda: driver.stats()["live"] == 1)
        assert b.cancel()
        rb = b.result(timeout=60)
        assert rb.finish_reason == "cancelled" and rb.tokens == ()
        assert "before admission" in rb.error
        assert not b.cancel()  # already finished
        assert a.cancel()      # resident: routed to engine.cancel
        ra = a.result(timeout=60)
        assert ra.finish_reason in ("cancelled", "length")
        assert driver.stats()["frontend_cancelled"] == 1
        driver.close()

    def test_drain_sheds_queue_and_finishes_residents(self, small_model):
        ecfg = EngineConfig(max_slots=1, capacity=64)
        driver = EngineDriver(_engine(small_model, ecfg)).start()
        a = driver.submit(PROMPTS[0], SamplingParams(max_new_tokens=64))
        b = driver.submit(PROMPTS[1], SamplingParams(max_new_tokens=4))
        # drain with a resident and b still queued: only b sheds
        assert _wait_until(lambda: driver.stats()["live"] == 1)
        assert driver.drain(timeout=120)
        assert a.result(timeout=0.0).finish_reason == "length"
        rb = b.result(timeout=0.0)
        assert rb.finish_reason == "rejected" and "draining" in rb.error
        late = driver.submit(PROMPTS[2], SamplingParams(max_new_tokens=4))
        assert late.result(timeout=60).finish_reason == "rejected"
        driver.close()

    def test_call_and_stats_while_running(self, small_model):
        driver = EngineDriver(_engine(small_model)).start()
        h = driver.submit(PROMPTS[0], SamplingParams(max_new_tokens=16))
        snap = driver.call(lambda eng: eng.health())
        assert snap is not None
        with pytest.raises(TypeError):
            driver.submit("text prompt")
        with pytest.raises(ValueError):
            driver.submit([])
        assert h.result(timeout=120).finish_reason == "length"
        s = driver.stats()
        assert s["submitted"] == 1 and s["retired"] == 1
        assert "serving_frontend_shed_total" in driver.call(
            lambda eng: eng.obs.registry.render_prometheus())
        driver.close()


# ---------------------------------------------------------------------------
# HTTP endpoint over a loopback socket
# ---------------------------------------------------------------------------

def _post(base, obj, path="/v1/completions", method="POST"):
    """(status, headers, parsed JSON body) — HTTP errors included."""
    data = json.dumps(obj).encode() if obj is not None else None
    req = urllib.request.Request(base + path, data=data, method=method,
                                 headers={"Content-Type": "application/json"})
    try:
        with urllib.request.urlopen(req, timeout=120) as resp:
            return resp.status, dict(resp.headers), json.loads(resp.read())
    except urllib.error.HTTPError as e:
        return e.code, dict(e.headers), json.loads(e.read())


def _sse(base, obj):
    req = urllib.request.Request(base + "/v1/completions",
                                 data=json.dumps(obj).encode(),
                                 headers={"Content-Type": "application/json"})
    tokens, result = [], None
    with urllib.request.urlopen(req, timeout=120) as resp:
        headers = dict(resp.headers)
        for raw in resp:
            line = raw.decode().strip()
            if not line.startswith("data: ") or line == "data: [DONE]":
                continue
            ev = json.loads(line[len("data: "):])
            if "token" in ev:
                tokens.append(ev["token"])
            else:
                result = ev
    return tokens, result, headers


@pytest.fixture()
def http_env(small_model):
    driver = EngineDriver(_engine(small_model)).start()
    srv = ThreadedHttpServer(driver).start()
    yield driver, f"http://{srv.host}:{srv.port}", srv
    srv.stop()
    driver.close(timeout=60)


class TestHttpServer:
    def test_wire_bit_identical_and_request_id(self, small_model, http_env):
        driver, base, _srv = http_env
        reqs = [(PROMPTS[i], SamplingParams(max_new_tokens=6, seed=i))
                for i in range(3)]
        ref = _coop_reference(small_model, reqs)
        for p, sp in reqs:
            status, headers, body = _post(base, {
                "prompt": p, "max_new_tokens": 6, "seed": sp.seed})
            assert status == 200
            assert body["finish_reason"] == "length"
            assert tuple(body["tokens"]) == ref[(tuple(p), sp.seed)]
            assert headers["X-Request-Id"] == str(body["id"])
        toks, result, headers = _sse(base, {
            "prompt": PROMPTS[0], "max_new_tokens": 6, "seed": 0,
            "stream": True})
        assert tuple(toks) == ref[(tuple(PROMPTS[0]), 0)]
        assert result["finish_reason"] == "length"
        assert tuple(result["tokens"]) == tuple(toks)
        assert "X-Request-Id" in headers

    def test_healthz_and_metrics(self, http_env):
        _driver, base, _srv = http_env
        status, _h, body = _post(base, None, path="/healthz", method="GET")
        assert status == 200 and body["ok"] is True
        req = urllib.request.Request(base + "/metrics")
        with urllib.request.urlopen(req, timeout=60) as resp:
            assert resp.status == 200
            assert resp.headers["Content-Type"].startswith("text/plain")
            text = resp.read().decode()
        assert "# TYPE" in text
        assert "serving_frontend_shed_total" in text
        assert "serving_frontend_queue_depth" in text

    def test_http_errors(self, http_env):
        _driver, base, _srv = http_env
        assert _post(base, None, path="/nope", method="GET")[0] == 404
        assert _post(base, None, method="GET")[0] == 405  # completions
        assert _post(base, {"prompt": "text"})[0] == 400
        assert _post(base, {"prompt": [1, 2], "bogus": 1})[0] == 400
        assert _post(base, {"prompt": []})[0] == 400
        status, _h, body = _post(base, {"prompt": [1], "temperature": -1})
        assert status == 400 and "error" in body

    def test_rejected_maps_429_with_retry_after(self, small_model):
        fair = FairScheduler(tenant_max_resident_tokens=8)
        driver = EngineDriver(_engine(small_model), fairness=fair).start()
        srv = ThreadedHttpServer(driver).start()
        try:
            status, headers, body = _post(
                f"http://{srv.host}:{srv.port}",
                {"prompt": [1, 2, 3], "max_new_tokens": 16})
            assert status == 429
            assert headers["Retry-After"] == "1"
            assert body["finish_reason"] == "rejected"
            assert "never fit" in body["error"]
        finally:
            srv.stop()
            driver.close(timeout=60)

    def test_frontend_timeout_maps_504(self, small_model):
        """A request that deadlines while still in the fair queue (slot
        held by a long request, virtual clock jumped past its TTFT
        budget) surfaces as HTTP 504."""
        clock = VirtualClock()
        eng = _engine(small_model,
                      EngineConfig(max_slots=1, capacity=64),
                      plan=FaultPlan(), clock=clock)
        driver = EngineDriver(eng).start()
        srv = ThreadedHttpServer(driver).start()
        try:
            base = f"http://{srv.host}:{srv.port}"
            hog = driver.submit([1, 2, 3],
                                SamplingParams(max_new_tokens=400))
            got = {}

            def post():
                got["resp"] = _post(base, {"prompt": [4, 5],
                                           "max_new_tokens": 4,
                                           "ttft_deadline_s": 5.0})

            th = threading.Thread(target=post)
            th.start()
            # wait until the hog is resident AND the HTTP request is the
            # one waiting in the fair queue, then expire its budget
            assert _wait_until(lambda: driver.stats()["live"] == 1
                               and driver.stats()["pending"] == 1)
            driver.call(lambda _eng: clock.advance(10.0))
            th.join(timeout=120)
            assert not th.is_alive()
            status, _headers, body = got["resp"]
            assert status == 504
            assert body["finish_reason"] == "timeout"
            hog.cancel()
        finally:
            srv.stop()
            driver.close(timeout=60)

    def test_engine_error_maps_500(self, small_model):
        plan = FaultPlan().nan_logits(uid=0, gen_index=0)
        ecfg = EngineConfig(max_slots=2, capacity=64, quarantine_steps=None)
        driver = EngineDriver(_engine(small_model, ecfg, plan=plan)).start()
        srv = ThreadedHttpServer(driver).start()
        try:
            status, _h, body = _post(f"http://{srv.host}:{srv.port}",
                                     {"prompt": [1, 2], "max_new_tokens": 4})
            assert status == 500
            assert body["finish_reason"] == "error"
        finally:
            srv.stop()
            driver.close(timeout=60)

    def test_disconnect_mid_stream_cancels(self, http_env):
        driver, base, srv = http_env
        body = json.dumps({"prompt": [1, 2, 3], "max_new_tokens": 300,
                           "stream": True}).encode()
        s = socket.create_connection((srv.host, srv.port), timeout=60)
        s.sendall((f"POST /v1/completions HTTP/1.1\r\nHost: t\r\n"
                   f"Content-Type: application/json\r\n"
                   f"Content-Length: {len(body)}\r\n\r\n").encode() + body)
        buf = b""
        while b"data: " not in buf:  # at least one token on the wire
            chunk = s.recv(4096)
            assert chunk, "stream closed before first token"
            buf += chunk
        s.close()  # client walks away mid-generation
        deadline = time.monotonic() + 60
        while time.monotonic() < deadline:
            if any(r.finish_reason == "cancelled" for r in driver.results()):
                break
            time.sleep(0.05)
        cancelled = [r for r in driver.results()
                     if r.finish_reason == "cancelled"]
        assert cancelled, "disconnect did not cancel the request"
        assert len(cancelled[0].tokens) < 300  # it genuinely stopped early


# ---------------------------------------------------------------------------
# serve.py: graceful signal-driven shutdown (subprocess)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_serve_sigint_drains_and_flushes(tmp_path):
    """SIGINT mid-run: queued requests cancel, residents finish, the
    drain tables print, --metrics-out flushes, exit code 0."""
    metrics = tmp_path / "final.prom"
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.serve", "--no-quantize",
         "--requests", "6", "--max-new", "200", "--slots", "2",
         "--metrics-out", str(metrics)],
        cwd=ROOT, stdout=subprocess.PIPE, stderr=subprocess.STDOUT,
        text=True, env={**os.environ, "PYTHONPATH": str(ROOT / "src"),
                        "PYTHONUNBUFFERED": "1"})
    try:
        booted = False
        for line in proc.stdout:
            if line.startswith("[serve] boot"):
                booted = True
                break
        assert booted, "serve.py never finished booting"
        proc.send_signal(signal.SIGINT)
        out = proc.stdout.read()
        rc = proc.wait(timeout=240)
    finally:
        if proc.poll() is None:
            proc.kill()
    assert rc == 0, out
    assert "drained:" in out
    assert "request latency (ms):" in out       # full epilogue ran
    assert metrics.exists() and "# TYPE" in metrics.read_text()
