"""Baseline PTQ methods (RTN/GPTQ/AWQ/BiLLM-style) sanity + ordering.

The paper's central comparison (Tables 1/2/9): PTQTP at 1.58 bit should land
between binary PTQ and 3-bit grouped methods in reconstruction quality.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.baselines.awq import awq_quantize
from repro.core.baselines.billm import billm_quantize
from repro.core.baselines.gptq import gptq_quantize
from repro.core.baselines.rtn import rtn_quantize
from repro.core.ptqtp import PTQTPConfig, ptqtp_dequantize, ptqtp_quantize


def _w(shape=(64, 512), seed=0):
    # heavy-tailed, per-column scaled — LLM-like weight statistics
    r = np.random.default_rng(seed)
    w = r.standard_t(4, size=shape).astype(np.float32)
    w *= np.exp(r.normal(0, 0.5, size=(1, shape[1]))).astype(np.float32)
    return jnp.asarray(w * 0.02)


def _x(d, seed=1):
    return jnp.asarray(np.random.default_rng(seed)
                       .standard_normal((256, d), dtype=np.float32))


def _rel(w, w_hat):
    return float(jnp.linalg.norm(w - w_hat) / jnp.linalg.norm(w))


class TestEachBaselineRuns:
    def test_rtn(self):
        w = _w()
        for bits in (2, 3, 4):
            w_hat, meta = rtn_quantize(w, bits=bits, group_size=128)
            assert w_hat.shape == w.shape
            assert _rel(w, w_hat) < 1.0
            assert int(meta["q"].max()) <= 2 ** bits - 1

    def test_gptq(self):
        """GPTQ optimizes the x-weighted error ‖x(W-Ŵ)ᵀ‖, not plain ‖W-Ŵ‖ —
        assert in its own metric."""
        w = _w()
        x = _x(512)
        w_hat, _ = gptq_quantize(w, x, bits=3, group_size=128)
        assert w_hat.shape == w.shape
        w_rtn, _ = rtn_quantize(w, bits=3, group_size=128)
        err_g = float(jnp.linalg.norm(x @ (w - w_hat).T))
        err_r = float(jnp.linalg.norm(x @ (w - w_rtn).T))
        assert np.isfinite(err_g) and err_g <= err_r * 1.02, (err_g, err_r)

    def test_awq(self):
        w = _w()
        w_hat, meta = awq_quantize(w, _x(512), bits=3, group_size=128)
        assert w_hat.shape == w.shape
        assert _rel(w, w_hat) < 0.5

    def test_billm(self):
        w = _w()
        w_hat, meta = billm_quantize(w, _x(512))
        assert w_hat.shape == w.shape
        assert _rel(w, w_hat) < 1.0


class TestOrdering:
    """Reconstruction-error ordering on LLM-like weights (Table 1 ordering,
    reproduced at the matrix level)."""

    def test_ptqtp_between_binary_and_4bit(self):
        w = _w(seed=7)
        q = ptqtp_quantize(w, PTQTPConfig(t_max=30))
        e_ptqtp = _rel(w, ptqtp_dequantize(q))
        e_billm = _rel(w, billm_quantize(w)[0])
        e_rtn4 = _rel(w, rtn_quantize(w, bits=4, group_size=128)[0])
        e_rtn2 = _rel(w, rtn_quantize(w, bits=2, group_size=128)[0])
        # PTQTP (1.58 b) beats binary-residual and 2-bit RTN ...
        assert e_ptqtp < e_billm, (e_ptqtp, e_billm)
        assert e_ptqtp < e_rtn2, (e_ptqtp, e_rtn2)
        # ... and 4-bit keeps an edge (sanity that we don't overclaim)
        assert e_rtn4 < e_ptqtp, (e_rtn4, e_ptqtp)

    def test_ptqtp_competitive_with_3bit(self):
        """Paper: PTQTP ≈ grouped 3-bit quality at 1.58 bits of storage."""
        errs_p, errs_3 = [], []
        for seed in range(3):
            w = _w(seed=seed)
            q = ptqtp_quantize(w, PTQTPConfig(t_max=30))
            errs_p.append(_rel(w, ptqtp_dequantize(q)))
            errs_3.append(_rel(w, rtn_quantize(w, bits=3, group_size=128)[0]))
        assert np.mean(errs_p) < 1.35 * np.mean(errs_3), (errs_p, errs_3)

    def test_gptq_beats_rtn_weighted_error(self):
        """GPTQ's Hessian compensation wins in the x-weighted metric."""
        w = _w(seed=9)
        x = _x(512, seed=10)
        w_rtn, _ = rtn_quantize(w, bits=3, group_size=128)
        w_gptq, _ = gptq_quantize(w, x, bits=3, group_size=128)
        err_rtn = float(jnp.linalg.norm(x @ (w - w_rtn).T))
        err_gptq = float(jnp.linalg.norm(x @ (w - w_gptq).T))
        assert err_gptq <= err_rtn * 1.02, (err_gptq, err_rtn)
