"""Per-architecture smoke tests + prefill/decode consistency.

Every assigned arch instantiates its reduced config, runs one forward/train
step on CPU, and asserts output shapes + finiteness (the (f) deliverable).
Cache correctness: last-token logits must agree between the full forward,
prefill, and prefill-then-decode paths.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import (decode_step, forward, init_decode_state,
                          init_params, loss_fn, prefill)
from repro.optim.adamw import AdamW
from repro.training.train_step import init_train_state, make_train_step

ARCHS = list(configs.ARCH_IDS)


def _batch(cfg, b=2, s=16, seed=0):
    rng = np.random.default_rng(seed)
    if cfg.embed_inputs:
        toks = rng.integers(0, min(cfg.vocab_size, 256), (b, s))
        batch = {"tokens": jnp.asarray(toks, jnp.int32)}
    else:
        batch = {"embeddings": jnp.asarray(
            rng.standard_normal((b, s, cfg.d_model), dtype=np.float32))}
    batch["labels"] = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
class TestArchSmoke:
    def test_forward_shapes_and_finite(self, arch):
        cfg = configs.get_smoke_config(arch)
        params = init_params(cfg, jax.random.PRNGKey(0))
        batch = _batch(cfg)
        logits = forward(params, cfg, batch)
        assert logits.shape == (2, 16, cfg.vocab_size)
        assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))

    def test_one_train_step(self, arch):
        cfg = configs.get_smoke_config(arch)
        params = init_params(cfg, jax.random.PRNGKey(0))
        opt = AdamW(lr=1e-3)
        state = init_train_state(cfg, params, opt)
        step = jax.jit(make_train_step(cfg, opt))
        batch = _batch(cfg)
        new_state, metrics = step(state, batch)
        assert np.isfinite(float(metrics["loss"]))
        assert int(new_state["step"]) == 1
        # params actually moved
        moved = jax.tree.map(
            lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                               - b.astype(jnp.float32)))),
            state["params"], new_state["params"])
        assert max(jax.tree.leaves(moved)) > 0

    def test_prefill_decode_consistency(self, arch):
        """forward(x)[:, -1] == prefill(x) logits; and prefill(x[:, :-1])
        then decode(x[:, -1]) matches too — the cache-correctness oracle."""
        cfg = configs.get_smoke_config(arch)
        params = init_params(cfg, jax.random.PRNGKey(1))
        batch = _batch(cfg, b=2, s=8, seed=1)
        del batch["labels"]
        full = forward(params, cfg, batch)
        last_full = np.asarray(full[:, -1], np.float32)

        lg_pre, _ = prefill(params, cfg, batch, capacity=16)
        np.testing.assert_allclose(np.asarray(lg_pre, np.float32), last_full,
                                   rtol=2e-2, atol=2e-2)

        if cfg.embed_inputs:
            head = {"tokens": batch["tokens"][:, :-1]}
            tail = batch["tokens"][:, -1]
        else:
            head = {"embeddings": batch["embeddings"][:, :-1]}
            tail = batch["embeddings"][:, -1]
        _, state = prefill(params, cfg, head, capacity=16)
        lg_dec, state2 = decode_step(params, cfg, state, tail)
        np.testing.assert_allclose(np.asarray(lg_dec, np.float32), last_full,
                                   rtol=2e-2, atol=2e-2)
        assert int(state2["pos"][0]) == 8

    def test_decode_state_structure(self, arch):
        cfg = configs.get_smoke_config(arch)
        st = init_decode_state(cfg, batch=2, capacity=16)
        assert st["pos"].shape == (2,)
        spec = jax.eval_shape(lambda: init_decode_state(cfg, 2, 16))
        same = jax.tree.map(lambda a, b: a.shape == b.shape and
                            a.dtype == b.dtype, st, spec)
        assert all(jax.tree.leaves(same))


def test_param_counts_match_instantiated():
    """Analytic param_counts() (roofline MODEL_FLOPS source) must track the
    real parameter tree within the bias/norm margin — checked on the FULL
    configs via eval_shape (no allocation)."""
    for arch in ("qwen2-1.5b", "deepseek-moe-16b", "rwkv6-3b",
                 "gemma3-27b", "llama3-405b"):
        cfg = configs.get_config(arch)
        shapes = jax.eval_shape(lambda c=cfg: init_params(
            c, jax.random.PRNGKey(0)))
        real = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(shapes))
        analytic, _ = cfg.param_counts()
        assert abs(real - analytic) / real < 0.05, (arch, real, analytic)


def test_long_context_flags():
    """long_500k runs only for sub-quadratic archs (DESIGN.md §4)."""
    expected_long = {"rwkv6-3b", "recurrentgemma-2b", "gemma3-27b"}
    actual = {a for a in ARCHS
              if configs.get_config(a).supports_long_context}
    assert actual == expected_long
    cells = configs.runnable_cells()
    assert len(cells) == 33  # 40 - 7 documented skips


class TestInt8KVCache:
    """§Perf iteration 5: int8 KV cache correctness (beyond-paper feature)."""

    @pytest.mark.parametrize("arch", ["qwen2-1.5b", "gemma3-27b",
                                      "recurrentgemma-2b"])
    def test_prefill_decode_consistency_int8(self, arch):
        cfg = configs.get_smoke_config(arch).scaled(kv_cache_dtype="int8")
        params = init_params(cfg, jax.random.PRNGKey(1))
        batch = _batch(cfg, b=2, s=8, seed=1)
        del batch["labels"]
        full = forward(params, cfg, batch)
        last_full = np.asarray(full[:, -1], np.float32)
        head = {"tokens": batch["tokens"][:, :-1]}
        _, state = prefill(params, cfg, head, capacity=16)
        lg, _ = decode_step(params, cfg, state, batch["tokens"][:, -1])
        # int8 cache: slightly looser tolerance than bf16
        np.testing.assert_allclose(np.asarray(lg, np.float32), last_full,
                                   rtol=8e-2, atol=8e-2)

    def test_cache_is_actually_int8(self):
        from repro.models import init_decode_state

        cfg = configs.get_smoke_config("qwen2-1.5b").scaled(
            kv_cache_dtype="int8")
        st = init_decode_state(cfg, batch=2, capacity=16)
        k = st["blocks"]["b0"]["k"]
        assert k.dtype == jnp.int8
        assert "k_scale" in st["blocks"]["b0"]

    def test_int8_cache_halves_bytes(self):
        from repro.models import init_decode_state

        def nbytes(cfg):
            st = init_decode_state(cfg, batch=2, capacity=64)
            return sum(x.size * x.dtype.itemsize
                       for x in jax.tree.leaves(st["blocks"])
                       if x.dtype != jnp.int32)

        base = configs.get_smoke_config("qwen2-1.5b").scaled(
            param_dtype="bfloat16", activation_dtype="bfloat16")
        b16 = nbytes(base)
        i8 = nbytes(base.scaled(kv_cache_dtype="int8"))
        assert i8 < 0.6 * b16, (i8, b16)
