"""Zero-perturbation serving observability (serving contract v1.3).

The keystone assertions:

* **Zero perturbation** — a request's tokens are bit-identical with
  tracing on, off, or the bundle left unconfigured, on both schedulers.
* **Exact reconciliation** — under a VirtualClock, trace span timestamps
  and durations equal the ``RequestResult`` timing fields, and histogram
  percentiles equal numpy percentiles of those same numbers.
* **Monotonicity** — every registry counter is non-decreasing across
  snapshots of any seeded fault-plan run, and the page pool never
  over-counts (``pages_free + pages_used <= max_pages``).
* **Single clock** — a static guard bans raw wall-clock calls from the
  serving and model layers (everything routes through
  ``repro.runtime.clock``, which a ``VirtualClock`` substitutes).
"""

import json
import re
from pathlib import Path

import jax
import numpy as np
import pytest

from repro import configs
from repro.models import init_params
from repro.runtime.monitor import (HEARTBEAT_SCHEMA, HeartbeatMonitor,
                                   StragglerDetector)
from repro.serving import (EngineConfig, FaultInjector, FaultPlan,
                           SamplingParams, SerialAdmitEngine, ServingEngine,
                           VirtualClock)
from repro.serving.observability import (LATENCY_BUCKETS, PHASES,
                                         SERVING_METRICS, SPEC_BY_NAME,
                                         Histogram, MetricsRegistry,
                                         Observability, TraceRecorder,
                                         request_track)

ENGINES = [ServingEngine, SerialAdmitEngine]


@pytest.fixture(scope="module")
def small_model():
    cfg = configs.get_smoke_config("qwen2-1.5b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def traced_engine(small_model, ecfg=None, cls=ServingEngine, trace=True,
                  plan=None):
    """Engine on a VirtualClock with a trace-enabled bundle. The clock
    starts past zero so every timestamp is distinguishable from the
    unset-field sentinel 0.0."""
    cfg, params = small_model
    clock = VirtualClock(start=1000.0)
    inj = FaultInjector(plan or FaultPlan(), clock=clock)
    eng = cls(params, cfg, ecfg or EngineConfig(max_slots=2, capacity=32),
              injector=inj, observability=Observability(trace=trace))
    return eng, clock


def drive(eng, clock, dt=0.125):
    """Drain the engine, ticking the virtual clock between steps so spans
    and waits get distinct, deterministic durations."""
    while eng.queue or any(s is not None for s in eng.slots):
        clock.advance(dt)
        eng.step()


# ---------------------------------------------------------------------------
# registry + instruments
# ---------------------------------------------------------------------------

class TestRegistry:
    def test_frozen_schema_is_well_formed(self):
        names = [s.name for s in SERVING_METRICS]
        assert len(names) == len(set(names))
        for s in SERVING_METRICS:
            assert s.kind in ("counter", "gauge", "histogram")
            assert s.name.startswith("serving_")
            if s.kind == "counter":
                assert s.name.endswith("_total"), s.name
            if s.kind == "histogram":
                assert s.buckets, s.name
        # every engine phase has its frozen seconds counter
        for p in PHASES:
            assert f"serving_phase_{p}_seconds_total" in SPEC_BY_NAME

    def test_frozen_kind_is_enforced(self):
        reg = MetricsRegistry()
        with pytest.raises(AssertionError):
            reg.gauge("serving_requests_completed_total")  # frozen: counter

    def test_duplicate_registration_rejected(self):
        reg = MetricsRegistry()
        reg.counter("x_total")
        with pytest.raises(ValueError):
            reg.counter("x_total")

    def test_polled_counter_reads_live_value(self):
        reg = MetricsRegistry()
        box = {"n": 0}
        assert reg.counter("polled_total", poll=lambda: box["n"]) is None
        box["n"] = 7
        assert reg.value("polled_total") == 7
        assert reg.counters() == {"polled_total": 7}

    def test_histogram_exact_percentiles_and_buckets(self):
        h = Histogram(buckets=(1.0, 2.0, 4.0))
        for v in (0.5, 1.5, 3.0, 8.0):
            h.observe(v)
        assert h.count == 4 and h.max == 8.0
        assert h.bucket_counts == [1, 1, 1, 1]  # per-bucket, +Inf last
        assert h.percentile(50) == float(np.percentile([0.5, 1.5, 3.0, 8.0],
                                                       50))
        assert h.percentile(100) == 8.0
        assert Histogram().percentile(99) == 0.0  # empty → 0.0, not NaN

    def test_histogram_window_bounds_memory(self):
        h = Histogram(buckets=(1.0,), window=8)
        for i in range(100):
            h.observe(float(i))
        assert h.count == 100              # cumulative stats keep counting
        assert len(h._samples) == 8        # raw window stays bounded
        assert h.percentile(0) == 92.0     # ...over the most recent 8

    def test_prometheus_exposition(self):
        reg = MetricsRegistry()
        c = reg.counter("serving_requests_completed_total",
                        help="requests finished")
        c.inc(3)
        hist = reg.histogram("serving_ttft_seconds",
                             buckets=LATENCY_BUCKETS, help="ttft")
        hist.observe(0.3)
        text = reg.render_prometheus()
        assert "# TYPE serving_requests_completed_total counter" in text
        assert "serving_requests_completed_total 3" in text
        assert "# TYPE serving_ttft_seconds histogram" in text
        assert 'serving_ttft_seconds_bucket{le="+Inf"} 1' in text
        assert "serving_ttft_seconds_count 1" in text
        # cumulative: every bucket >= 0.5 already includes the 0.3 sample
        assert 'serving_ttft_seconds_bucket{le="0.5"} 1' in text

    def test_jsonl_line_round_trips(self):
        reg = MetricsRegistry()
        reg.counter("a_total").inc(2)
        reg.histogram("h_seconds").observe(1.0)
        snap = json.loads(reg.jsonl_line(t=5.0))
        assert snap["t"] == 5.0 and snap["a_total"] == 2
        assert snap["h_seconds"]["count"] == 1


# ---------------------------------------------------------------------------
# trace recorder
# ---------------------------------------------------------------------------

class TestTraceRecorder:
    def test_ring_drops_oldest_and_counts(self):
        tr = TraceRecorder(capacity=4)
        for i in range(10):
            tr.instant(f"e{i}", ("engine", 0), float(i))
        assert len(tr) == 4 and tr.dropped == 6
        assert [e.name for e in tr.events()] == ["e6", "e7", "e8", "e9"]
        assert tr.chrome_trace()["otherData"]["dropped_events"] == 6

    def test_chrome_trace_format(self, tmp_path):
        tr = TraceRecorder()
        tr.complete("step", ("engine", 0), 1.0, 1.5,
                    args={"engine_step": 1})
        tr.instant("first_token", request_track(3), 1.25)
        doc = tr.chrome_trace()
        evs = doc["traceEvents"]
        span = next(e for e in evs if e.get("ph") == "X")
        assert span["ts"] == 1.0e6 and span["dur"] == 0.5e6  # microseconds
        inst = next(e for e in evs if e.get("ph") == "i")
        assert inst["s"] == "t" and inst["tid"] == 3
        # metadata names both tracks
        pnames = {e["args"]["name"] for e in evs
                  if e.get("name") == "process_name"}
        assert pnames == {"engine", "requests"}
        p = tmp_path / "trace.json"
        tr.write(p)
        assert json.loads(p.read_text())["displayTimeUnit"] == "ms"


# ---------------------------------------------------------------------------
# engine integration: reconciliation + zero perturbation
# ---------------------------------------------------------------------------

class TestEngineTracing:
    @pytest.mark.parametrize("cls", ENGINES)
    def test_spans_reconcile_with_result_timestamps(self, small_model, cls):
        """Under the VirtualClock, the trace is fully deterministic and the
        per-request spans equal the RequestResult timing fields exactly."""
        eng, clock = traced_engine(small_model, cls=cls)
        hs = [eng.submit([1, 2, 3], SamplingParams(max_new_tokens=4))]
        clock.advance(0.5)
        hs.append(eng.submit([4, 5], SamplingParams(max_new_tokens=3)))
        drive(eng, clock)
        results = [h.result() for h in hs]
        evs = eng.obs.trace.events()
        for h, r in zip(hs, results):
            track = request_track(h.uid)
            by_name = {e.name: e for e in evs if e.track == track}
            req = by_name["request"]
            assert req.ts == r.t_submit
            assert req.ts + req.dur == r.t_done
            assert req.args["finish_reason"] == r.finish_reason
            assert req.args["tokens"] == len(r.tokens)
            assert by_name["queued"].dur == pytest.approx(r.queue_wait)
            assert by_name["first_token"].ts == r.t_first
            decode = by_name["decode"]
            assert decode.ts == r.t_first and decode.ts + decode.dur == r.t_done
            assert by_name["prefill"].ts == h.t_admit
            # lifecycle ordering on the virtual timeline
            assert (by_name["submitted"].ts <= by_name["admitted"].ts
                    <= by_name["first_token"].ts <= by_name["retired"].ts)

    @pytest.mark.parametrize("cls", ENGINES)
    def test_histograms_reconcile_with_results(self, small_model, cls):
        eng, clock = traced_engine(small_model, cls=cls)
        hs = []
        for prompt, n in (([1, 2, 3], 4), ([4, 5], 3), ([6], 2)):
            hs.append(eng.submit(prompt, SamplingParams(max_new_tokens=n)))
            clock.advance(0.25)
        drive(eng, clock)
        results = [h.result() for h in hs]
        reg = eng.obs.registry
        ttfts = np.asarray([r.ttft for r in results])
        waits = np.asarray([r.queue_wait for r in results])
        for q in (50, 90, 99):
            assert reg.get_histogram("serving_ttft_seconds").percentile(q) \
                == float(np.percentile(ttfts, q))
            assert reg.get_histogram(
                "serving_queue_wait_seconds").percentile(q) \
                == float(np.percentile(waits, q))
        assert reg.value("serving_tokens_generated_total") \
            == sum(len(r.tokens) for r in results)

    @pytest.mark.parametrize("cls", ENGINES)
    def test_zero_perturbation(self, small_model, cls):
        """Bit-identical tokens with tracing on, off, and unconfigured —
        and no extra jit compilations from instrumentation."""
        cfg, params = small_model
        sp = SamplingParams(max_new_tokens=6, temperature=0.8, seed=11)
        runs = []
        for obs in (None, Observability(trace=False), Observability(trace=True)):
            eng = cls(params, cfg, EngineConfig(max_slots=2, capacity=32),
                      observability=obs)
            hs = [eng.submit([5, 9, 17, 2], sp),
                  eng.submit([1, 2], SamplingParams(max_new_tokens=4))]
            eng.run()
            runs.append(([h.result().tokens for h in hs],
                         eng.compile_stats()["n_prefill_compiles"],
                         eng.compile_stats()["n_decode_compiles"]))
        assert runs[0] == runs[1] == runs[2]

    def test_step_phase_spans_and_counters(self, small_model):
        eng, _ = traced_engine(small_model)
        eng.submit([1, 2, 3], SamplingParams(max_new_tokens=3))
        eng.run()
        reg = eng.obs.registry
        # phase seconds flowed into their frozen counters (virtual clock
        # never advances on its own, so values are >= 0 and finite)
        for p in ("sweep", "admit", "prefill_dispatch", "decode_dispatch",
                  "collect"):
            assert reg.value(f"serving_phase_{p}_seconds_total") >= 0.0
        steps = [e for e in eng.obs.trace.events()
                 if e.name == "step" and e.track == ("engine", 0)]
        assert len(steps) == eng.engine_steps
        assert [e.args["engine_step"] for e in steps] \
            == list(range(1, eng.engine_steps + 1))

    def test_trace_ring_overflow_reaches_registry(self, small_model):
        eng, _ = traced_engine(small_model)
        eng.obs.trace.capacity = 4  # shrink post-hoc: force overflow
        eng.submit([1, 2, 3], SamplingParams(max_new_tokens=4))
        eng.run()
        assert eng.obs.trace.dropped > 0
        assert eng.obs.registry.value("serving_trace_dropped_total") \
            == eng.obs.trace.dropped

    def test_health_reads_the_registry(self, small_model):
        """health() is derived from the registry — the two surfaces can
        never disagree."""
        eng, _ = traced_engine(small_model)
        eng.submit([1, 2], SamplingParams(max_new_tokens=2))
        eng.run()
        snap, reg = eng.health(), eng.obs.registry
        assert snap.completed == reg.value("serving_requests_completed_total")
        assert snap.queue_depth == reg.value("serving_queue_depth")
        assert snap.free_slots == reg.value("serving_free_slots")
        d = eng.obs.digest()
        assert d["serving_requests_completed_total"] == snap.completed
        assert "ttft_p50_s" in d


# ---------------------------------------------------------------------------
# property test: monotone counters + page-pool conservation under faults
# ---------------------------------------------------------------------------

class TestCounterMonotonicity:
    def _drive_and_check(self, eng, clock, submits):
        prev = eng.obs.registry.counters()
        paged = eng.paged
        max_pages = eng.alloc.n_pages if paged else None
        for i, (prompt, sp) in enumerate(submits):
            eng.submit(prompt, sp)
            clock.advance(0.25)
            eng.step()
            cur = eng.obs.registry.counters()
            for name, v in cur.items():
                assert v >= prev[name], f"{name} decreased: {prev[name]}->{v}"
            if paged:
                free = eng.obs.registry.value("serving_pages_free")
                used = eng.obs.registry.value("serving_pages_used")
                assert free + used <= max_pages
            prev = cur
        while eng.queue or any(s is not None for s in eng.slots):
            clock.advance(0.25)
            eng.step()
            cur = eng.obs.registry.counters()
            for name, v in cur.items():
                assert v >= prev[name], f"{name} decreased: {prev[name]}->{v}"
            if paged:
                free = eng.obs.registry.value("serving_pages_free")
                used = eng.obs.registry.value("serving_pages_used")
                assert free + used <= max_pages
            prev = cur

    def test_counters_monotone_under_fault_plan(self, small_model):
        """Across a run with NaN poisoning, deadline expiry, and shedding,
        every counter in successive snapshots is non-decreasing."""
        plan = (FaultPlan().nan_logits(uid=0, gen_index=2)
                .stall_clock(at_step=5, advance_s=60.0))
        eng, clock = traced_engine(
            small_model, EngineConfig(max_slots=2, capacity=32, max_queue=3),
            plan=plan)
        submits = [([1 + i, 2, 3], SamplingParams(
            max_new_tokens=4 + i, deadline_s=30.0, seed=i))
            for i in range(6)]
        self._drive_and_check(eng, clock, submits)
        # the plan really did exercise the fault paths
        reg = eng.obs.registry
        assert reg.value("serving_requests_error_total") >= 1
        assert reg.value("serving_requests_timeout_total") \
            + reg.value("serving_requests_completed_total") >= 1

    def test_counters_monotone_paged_pool_conserved(self, small_model):
        # prefix_cache off so a drained pool owes zero pages (the cache
        # intentionally keeps published prefix pages referenced)
        eng, clock = traced_engine(small_model, EngineConfig(
            max_slots=2, capacity=32, kv_layout="paged", page_size=8,
            prefix_cache=False))
        submits = [([1, 2, 3, 4, 5, 6, 7, 8, 9], SamplingParams(
            max_new_tokens=6, seed=i)) for i in range(4)]
        self._drive_and_check(eng, clock, submits)
        reg = eng.obs.registry
        assert reg.value("serving_pages_alloc_total") > 0
        assert reg.value("serving_pages_release_total") > 0
        # drained: every page back in the pool
        assert reg.value("serving_pages_used") == 0


# ---------------------------------------------------------------------------
# the single-clock invariant (static guard)
# ---------------------------------------------------------------------------

class TestClockGuard:
    def test_no_raw_wall_clock_in_serving_or_models(self):
        """Every timestamp in the serving and model layers must route
        through repro.runtime.clock, so a VirtualClock substitution covers
        *all* of them. A raw time.time()/perf_counter() call would fork the
        time domain and silently break trace determinism."""
        src = Path(__file__).resolve().parent.parent / "src" / "repro"
        pat = re.compile(r"\btime\.(time|perf_counter|monotonic)\s*\(")
        offenders = []
        for layer in ("serving", "models"):
            for p in sorted((src / layer).rglob("*.py")):
                for i, line in enumerate(p.read_text().splitlines(), 1):
                    if pat.search(line):
                        offenders.append(f"{p.relative_to(src)}:{i}")
        assert not offenders, (
            "raw wall-clock calls found (route through repro.runtime.clock "
            f"instead): {offenders}")

    def test_clock_module_is_the_one_wall_clock_owner(self):
        from repro.runtime import clock as rtclock
        assert rtclock.now() <= rtclock.now()          # monotone
        assert isinstance(rtclock.wall_now(), float)


# ---------------------------------------------------------------------------
# heartbeat schema versioning (satellite)
# ---------------------------------------------------------------------------

class TestHeartbeatSchema:
    def test_current_beat_carries_schema_and_digest(self, small_model,
                                                    tmp_path):
        eng, _ = traced_engine(small_model)
        eng.submit([1, 2], SamplingParams(max_new_tokens=2))
        eng.run()
        mon = HeartbeatMonitor(str(tmp_path), host_id=0)
        eng.health().beat(mon, step_time_s=0.1, metrics=eng.obs.digest())
        [beat] = StragglerDetector(str(tmp_path)).read()
        assert beat["schema"] == HEARTBEAT_SCHEMA
        assert beat["serving_requests_completed_total"] == 1
        assert beat["queue_depth"] == 0

    def test_pre_metrics_heartbeat_still_parses(self, tmp_path):
        """A v1 payload (pre-paging/pre-metrics writers: no schema, no
        step_time_s, no digest keys) must parse and assess — a fleet
        mid-upgrade never KeyErrors the detector."""
        d = tmp_path / "heartbeats"
        d.mkdir()
        (d / "host0000.json").write_text(json.dumps(
            {"host": 0, "step": 12, "t": 1000.0}))
        (d / "host0001.json").write_text(json.dumps(   # v2 writer alongside
            {"schema": 2, "host": 1, "step": 12, "t": 1000.0,
             "step_time_s": 0.5, "serving_requests_completed_total": 3}))
        (d / "host0002.json").write_text("{not json")  # torn read
        det = StragglerDetector(str(tmp_path), dead_after_s=120.0)
        beats = det.read()
        assert [b["host"] for b in beats] == [0, 1]
        assert beats[0]["schema"] == 1 and beats[0]["step_time_s"] is None
        report = det.assess(now=1001.0)
        assert sorted(report["healthy"]) == [0, 1]
        # the straggler median ignores hosts that report no step time
        assert report["median_step_s"] == 0.5

    def test_unassessable_payload_skipped_not_crashed(self, tmp_path):
        d = tmp_path / "heartbeats"
        d.mkdir()
        (d / "host0000.json").write_text(json.dumps({"step": 3}))  # no host/t
        (d / "host0001.json").write_text(json.dumps([1, 2, 3]))    # not a dict
        assert StragglerDetector(str(tmp_path)).read() == []
