"""Shared fixtures. NOTE: no XLA_FLAGS here — tests see 1 CPU device;
only launch/dryrun.py (own process) requests 512 placeholder devices."""

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def pytest_addoption(parser):
    import importlib.util

    if importlib.util.find_spec("pytest_timeout") is None:
        # pytest.ini sets `timeout` for CI (pytest-timeout is a CI-only
        # dep); register it as an inert ini option where the plugin is
        # absent so local runs neither warn nor fail
        parser.addini("timeout", "per-test timeout (pytest-timeout is not "
                      "installed: ignored)")


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
    config.addinivalue_line(
        "markers", "timeout(seconds): per-test wall cap (enforced by "
        "pytest-timeout in CI; inert where the plugin is absent)")
