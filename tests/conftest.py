"""Shared fixtures. NOTE: no XLA_FLAGS here — tests see 1 CPU device;
only launch/dryrun.py (own process) requests 512 placeholder devices."""

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
