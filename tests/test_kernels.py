"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles.

Kernels run in interpret mode (CPU container; TPU is the lowering target).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.packing import pack_trits
from repro.core.ptqtp import PTQTPConfig, ptqtp_quantize
from repro.kernels.ptqtp_search import ops as search_ops
from repro.kernels.ptqtp_search import ref as search_ref
from repro.kernels.ternary_matmul import ops as tm_ops
from repro.kernels.ternary_matmul import ref as tm_ref


def _quantized(n_out, d_in, seed=0, g=128):
    w = jnp.asarray(np.random.default_rng(seed)
                    .standard_normal((n_out, d_in), dtype=np.float32))
    q = ptqtp_quantize(w, PTQTPConfig(group_size=g, t_max=5))
    return q, pack_trits(q.t1), pack_trits(q.t2)


class TestTernaryMatmul:
    @pytest.mark.parametrize("b,d_in,d_out", [
        (1, 128, 128),      # minimal tile
        (4, 256, 512),      # multi-group
        (3, 384, 256),      # non-pow2 batch/contraction
        (16, 512, 384),     # wider
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_oracle(self, b, d_in, d_out, dtype):
        q, t1p, t2p = _quantized(d_out, d_in)
        x = jnp.asarray(np.random.default_rng(1)
                        .standard_normal((b, d_in), dtype=np.float32)
                        ).astype(dtype)
        y_k = tm_ops.ternary_matmul(x, t1p, t2p, q.alpha, group_size=128,
                                    backend="pallas")
        y_r = tm_ref.ternary_matmul_ref(x.astype(jnp.float32), q.t1, q.t2,
                                        q.alpha, group_size=128)
        tol = 1e-5 if dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(np.asarray(y_k, np.float32),
                                   np.asarray(y_r), rtol=tol, atol=tol * 10)

    @pytest.mark.parametrize("backend", ["grouped", "pallas", "ref"])
    def test_backends_agree(self, backend):
        q, t1p, t2p = _quantized(256, 384, seed=2)
        x = jnp.asarray(np.random.default_rng(3)
                        .standard_normal((5, 384), dtype=np.float32))
        y = tm_ops.ternary_matmul(x, t1p, t2p, q.alpha, group_size=128,
                                  backend=backend)
        y_r = tm_ref.ternary_matmul_ref(x, q.t1, q.t2, q.alpha, group_size=128)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_r),
                                   rtol=1e-4, atol=1e-4)

    def test_batched_leading_dims(self):
        """(B, S, d_in) activations — the in-model call shape."""
        q, t1p, t2p = _quantized(128, 256, seed=4)
        x = jnp.asarray(np.random.default_rng(5)
                        .standard_normal((2, 7, 256), dtype=np.float32))
        y = tm_ops.ternary_matmul(x, t1p, t2p, q.alpha, group_size=128)
        assert y.shape == (2, 7, 128)
        y_r = tm_ref.ternary_matmul_ref(x.reshape(-1, 256), q.t1, q.t2,
                                        q.alpha, group_size=128)
        np.testing.assert_allclose(np.asarray(y).reshape(-1, 128),
                                   np.asarray(y_r), rtol=1e-4, atol=1e-4)

    def test_equals_dense_matmul_of_dequantized(self):
        """y == x @ Ŵᵀ where Ŵ is the dequantized matrix (end-to-end
        semantics of the multiplication-free path)."""
        from repro.core.ptqtp import ptqtp_dequantize

        q, t1p, t2p = _quantized(128, 256, seed=6)
        x = jnp.asarray(np.random.default_rng(7)
                        .standard_normal((3, 256), dtype=np.float32))
        y = tm_ops.ternary_matmul(x, t1p, t2p, q.alpha, group_size=128)
        w_hat = ptqtp_dequantize(q)  # (n_out, d_in)
        np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w_hat.T),
                                   rtol=1e-4, atol=1e-4)


class TestSmallMFastPath:
    """Decode fast path: the small-m fused kernel vs the ref oracle."""

    @pytest.mark.parametrize("m", [1, 3, 5])
    @pytest.mark.parametrize("n,d", [
        (128, 256),     # aligned n
        (96, 256),      # n < 128, not divisible by 128
        (192, 128),     # n > 128, not divisible by 128 (bn = 96)
    ])
    def test_small_m_parity(self, m, n, d):
        q, t1p, t2p = _quantized(n, d, seed=m)
        x = jnp.asarray(np.random.default_rng(m + 10)
                        .standard_normal((m, d), dtype=np.float32))
        y = tm_ops.ternary_matmul(x, t1p, t2p, q.alpha, group_size=128,
                                  backend="pallas")
        y_r = tm_ops.ternary_matmul(x, t1p, t2p, q.alpha, group_size=128,
                                    backend="ref")
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_r),
                                   rtol=1e-4, atol=1e-4)

    def test_small_m_kernel_direct(self):
        """The matvec kernel entry point itself, bypassing dispatch."""
        from repro.kernels.ternary_matmul.kernel import ternary_matvec_pallas

        q, t1p, t2p = _quantized(256, 384, seed=21)
        x = jnp.asarray(np.random.default_rng(22)
                        .standard_normal((4, 384), dtype=np.float32))
        y = ternary_matvec_pallas(x, t1p, t2p, q.alpha, group_size=128,
                                  block_n=128, interpret=True)
        y_r = tm_ref.ternary_matmul_ref(x, q.t1, q.t2, q.alpha, group_size=128)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_r),
                                   rtol=1e-4, atol=1e-4)

    def test_bf16_activation(self):
        q, t1p, t2p = _quantized(128, 256, seed=31)
        x = jnp.asarray(np.random.default_rng(32)
                        .standard_normal((2, 256), dtype=np.float32)
                        ).astype(jnp.bfloat16)
        y = tm_ops.ternary_matmul(x, t1p, t2p, q.alpha, group_size=128,
                                  backend="pallas")
        y_r = tm_ref.ternary_matmul_ref(x.astype(jnp.float32), q.t1, q.t2,
                                        q.alpha, group_size=128)
        np.testing.assert_allclose(np.asarray(y, np.float32), np.asarray(y_r),
                                   rtol=2e-2, atol=2e-1)


class TestBackendSelection:
    def test_auto_resolves_per_platform(self):
        # this suite runs on CPU: auto must pick the XLA grouped path
        assert tm_ops.resolve_backend("auto") == "grouped"
        assert tm_ops.resolve_backend(None) == "grouped"
        assert tm_ops.resolve_backend("auto", platform="tpu") == "pallas"
        assert tm_ops.resolve_backend("ref") == "ref"

    def test_auto_backend_matches_ref(self):
        q, t1p, t2p = _quantized(128, 256, seed=41)
        x = jnp.asarray(np.random.default_rng(42)
                        .standard_normal((3, 256), dtype=np.float32))
        y = tm_ops.ternary_matmul(x, t1p, t2p, q.alpha, group_size=128,
                                  backend="auto")
        y_r = tm_ref.ternary_matmul_ref(x, q.t1, q.t2, q.alpha, group_size=128)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_r),
                                   rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("n,cap,want", [
        (128, 128, 128), (256, 128, 128), (96, 128, 96), (192, 128, 96),
        (384, 128, 128), (259, 128, 37), (127, 128, 127), (97, 32, 1),
        (5504, 128, 128),
    ])
    def test_largest_divisor(self, n, cap, want):
        got = tm_ops._largest_divisor_at_most(n, cap)
        assert got == want
        assert n % got == 0 and got <= cap

    def test_unpacked_planes_dispatch(self):
        """int8 (pre-unpacked) planes: 'auto' adapts to grouped; an explicit
        ask for another backend fails loudly instead of being overridden."""
        from repro.core.packing import unpack_trits

        q, t1p, t2p = _quantized(128, 256, seed=51)
        t1, t2 = unpack_trits(t1p), unpack_trits(t2p)
        x = jnp.asarray(np.random.default_rng(52)
                        .standard_normal((2, 256), dtype=np.float32))
        y = tm_ops.ternary_matmul(x, t1, t2, q.alpha, group_size=128,
                                  backend="auto")
        y_r = tm_ref.ternary_matmul_ref(x, q.t1, q.t2, q.alpha, group_size=128)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_r),
                                   rtol=1e-4, atol=1e-4)
        with pytest.raises(ValueError, match="packed uint8"):
            tm_ops.ternary_matmul(x, t1, t2, q.alpha, group_size=128,
                                  backend="pallas")

    def test_tile_selection_cached(self):
        tm_ops._select_tiles(7, 4096)
        hits_before = tm_ops._select_tiles.cache_info().hits
        tm_ops._select_tiles(7, 4096)  # identical call must hit the cache
        assert tm_ops._select_tiles.cache_info().hits == hits_before + 1
        assert tm_ops._select_tiles(7, 4096) == (True, 7, 128)
        assert tm_ops._select_tiles(256, 384) == (False, 128, 128)


class TestPTQTPSearchKernel:
    @pytest.mark.parametrize("r,g", [(8, 128), (32, 128), (128, 128),
                                     (16, 256)])
    def test_matches_oracle(self, r, g):
        rng = np.random.default_rng(r)
        w = jnp.asarray(rng.standard_normal((r, g), dtype=np.float32))
        alpha = jnp.asarray(rng.standard_normal((r, 2), dtype=np.float32))
        t1k, t2k = search_ops.ptqtp_search(w, alpha)
        t1r, t2r = search_ref.ptqtp_search_ref(w, alpha)
        np.testing.assert_array_equal(np.asarray(t1k), np.asarray(t1r))
        np.testing.assert_array_equal(np.asarray(t2k), np.asarray(t2r))

    def test_selection_is_optimal(self):
        """Every selected pair achieves the elementwise minimum error."""
        rng = np.random.default_rng(9)
        w = jnp.asarray(rng.standard_normal((16, 128), dtype=np.float32))
        alpha = jnp.asarray(rng.standard_normal((16, 2), dtype=np.float32))
        t1, t2 = search_ops.ptqtp_search(w, alpha)
        chosen = (np.asarray(alpha)[:, :1] * np.asarray(t1)
                  + np.asarray(alpha)[:, 1:] * np.asarray(t2))
        err_chosen = (np.asarray(w) - chosen) ** 2
        cand = search_ref.CANDIDATES
        vals = np.asarray(alpha) @ cand.T  # (R, 9)
        err_best = ((np.asarray(w)[:, :, None] - vals[:, None, :]) ** 2
                    ).min(-1)
        np.testing.assert_allclose(err_chosen, err_best, rtol=1e-5,
                                   atol=1e-6)

    def test_quantizer_kernel_route_agrees(self):
        """PTQTPConfig(use_search_kernel=True) — full quantizer through the
        Pallas kernel matches the jnp route."""
        from repro.core.ptqtp import ptqtp_error

        w = jnp.asarray(np.random.default_rng(11)
                        .standard_normal((8, 256), dtype=np.float32))
        q_j = ptqtp_quantize(w, PTQTPConfig(t_max=10))
        q_k = ptqtp_quantize(w, PTQTPConfig(t_max=10, use_search_kernel=True))
        np.testing.assert_array_equal(np.asarray(q_j.t1), np.asarray(q_k.t1))
        np.testing.assert_allclose(np.asarray(q_j.alpha),
                                   np.asarray(q_k.alpha), rtol=1e-5)
        assert abs(float(ptqtp_error(w, q_j)) -
                   float(ptqtp_error(w, q_k))) < 1e-6
